"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core.chunked import ChunkedTensor
from repro.core.executor import DenseTable, execute
from repro.core.relational import (Collect, GroupAgg, Join, Project, Scan,
                                   Unnest, call, col, const, floordiv, key,
                                   mod, SCALAR, VEC, add, mul)
from repro.serving.pager import WeightPager

COMMON = dict(deadline=None, max_examples=25)


@settings(**COMMON)
@given(rows=st.integers(1, 12), cols=st.integers(1, 40),
       cs=st.integers(1, 16))
def test_chunk_roundtrip(rows, cols, cs):
    """from_dense∘to_dense == identity for any shape/chunk size (§3.1)."""
    x = np.random.default_rng(0).standard_normal((rows, cols)).astype(
        np.float32)
    ct = ChunkedTensor.from_dense("t", x, chunk_size=cs)
    assert ct.data.shape[-1] == min(cs, ct.data.shape[-1])
    np.testing.assert_array_equal(np.asarray(ct.to_dense()), x)


@settings(**COMMON)
@given(m=st.integers(1, 8), n=st.integers(1, 8),
       chunks=st.integers(1, 4), cs=st.sampled_from([2, 4, 8]))
def test_relational_matmul_equals_numpy(m, n, chunks, cs):
    """γ_{(i,j),SUM(dot)}(R_A ⋈_c R_B) == A·Bᵀ for any chunking (§2.2)."""
    k = chunks * cs
    rng = np.random.default_rng(m * 100 + n)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((n, k)).astype(np.float32)
    at = DenseTable(keys=(("i", m), ("c", chunks)),
                    cols={"a": jnp.asarray(a.reshape(m, chunks, cs))},
                    col_types={"a": VEC(cs)})
    bt = DenseTable(keys=(("j", n), ("c", chunks)),
                    cols={"b": jnp.asarray(b.reshape(n, chunks, cs))},
                    col_types={"b": VEC(cs)})
    plan = GroupAgg(
        input=Join(left=Scan("A", at.schema()), right=Scan("B", bt.schema()),
                   on=[("c", key("c"))]),
        group_keys=["i", "j"],
        aggs=[("s", "SUM", call("dot", col("a"), col("b")))])
    out = execute(plan, {"A": at, "B": bt})
    np.testing.assert_allclose(np.asarray(out.cols["s"]), a @ b.T,
                               rtol=1e-4, atol=1e-4)


@settings(**COMMON)
@given(size=st.integers(2, 48), split=st.integers(2, 8))
def test_key_split_merge_inverse(size, split):
    """π split ∘ π merge == identity on dense keys (free-dim manipulation)."""
    total = size * split
    x = np.arange(total, dtype=np.float32)
    t = DenseTable(keys=(("i", total),), cols={"v": jnp.asarray(x)},
                   col_types={"v": SCALAR})
    p1 = Project(input=Scan("t", t.schema()),
                 keys=[("a", size, floordiv(key("i"), const(split))),
                       ("b", split, mod(key("i"), const(split)))],
                 exprs=[("v", None, col("v"))])
    p2 = Project(input=p1,
                 keys=[("i", total, add(mul(key("a"), const(split)),
                                        key("b")))],
                 exprs=[("v", None, col("v"))])
    out = execute(p2, {"t": t})
    np.testing.assert_array_equal(np.asarray(out.cols["v"]), x)


@settings(**COMMON)
@given(rows=st.integers(1, 6), w=st.sampled_from([2, 4, 8]))
def test_unnest_collect_inverse(rows, w):
    x = np.random.default_rng(1).standard_normal((rows, w)).astype(np.float32)
    t = DenseTable(keys=(("r", rows),), cols={"v": jnp.asarray(x)},
                   col_types={"v": VEC(w)})
    plan = Collect(input=Unnest(input=Scan("t", t.schema()), vec_col="v"),
                   fold_key="e", scalar_col="x", vec_col="v")
    out = execute(plan, {"t": t})
    np.testing.assert_array_equal(np.asarray(out.cols["v"]), x)


@settings(**COMMON)
@given(m=st.integers(1, 8), t=st.integers(1, 6), k=st.integers(1, 24),
       cs=st.integers(1, 10))
def test_row_chunk_matmul_any_chunk_size(m, t, k, cs):
    """ROW_CHUNK matmul is exact for *any* chunk size, including
    non-divisors of the reduction dim — the padding tail is zeros and the
    dot ignores it (per-table chunk-size planning's correctness basis)."""
    rng = np.random.default_rng(m * 1000 + t * 10 + cs)
    x = rng.standard_normal((t, k)).astype(np.float32)
    w = rng.standard_normal((m, k)).astype(np.float32)
    xt = ChunkedTensor.from_dense("x", x, chunk_size=cs,
                                  key_names=("t",))
    wt = ChunkedTensor.from_dense("w", w, chunk_size=cs,
                                  key_names=("j",))
    assert xt.schema.pad == wt.schema.pad < cs  # padding invariant
    from repro.core.executor import table_from_chunked
    xd, wd = table_from_chunked(xt), table_from_chunked(wt)
    xd = DenseTable(keys=(("t", t), ("c", xt.schema.n_chunks)),
                    cols={"v": xd.cols["chunk"]},
                    col_types={"v": VEC(xt.schema.chunk_size)})
    plan = GroupAgg(
        input=Join(left=Scan("x", xd.schema()),
                   right=Scan("w", wd.schema()),
                   on=[("chunk_id", key("c"))]),
        group_keys=["t", "j"],
        aggs=[("s", "SUM", call("dot", col("v"), col("chunk")))])
    out = execute(plan, {"x": xd, "w": wd})
    np.testing.assert_allclose(np.asarray(out.cols["s"]), x @ w.T,
                               rtol=1e-4, atol=1e-4)


@settings(**COMMON)
@given(m=st.integers(1, 8), t=st.integers(1, 6), k=st.integers(1, 16),
       cs=st.integers(1, 8), cs_col=st.integers(1, 10))
def test_col_chunk_matmul_any_chunk_size(t, m, k, cs, cs_col):
    """COL_CHUNK matmul is exact for any (activation, column) chunk-size
    pair — the transposed table's padded output tail stays zero and is
    stripped, exercising the planner's free per-table output chunking."""
    from repro.core.executor import col_table_from_dense, table_from_chunked
    rng = np.random.default_rng(m * 777 + k * 13 + cs_col)
    x = rng.standard_normal((t, k)).astype(np.float32)
    w = rng.standard_normal((m, k)).astype(np.float32)
    xt = ChunkedTensor.from_dense("x", x, chunk_size=cs, key_names=("t",))
    nch, csx = xt.schema.n_chunks, xt.schema.chunk_size
    n_feat = nch * csx  # padded feature domain of the chunked activation
    xd = DenseTable(keys=(("t", t), ("c", nch)),
                    cols={"v": table_from_chunked(xt).cols["chunk"]},
                    col_types={"v": VEC(csx)})
    # transposed table over the same padded domain: the extra feature rows
    # are zero weights, so the padded positions cannot contribute
    wcol = col_table_from_dense(np.pad(w, ((0, 0), (0, n_feat - k))),
                                cs_col)
    n_out = wcol.keys[1][1]
    u = Unnest(input=Scan("x", xd.schema()), vec_col="v", elem_key="e",
               elem_col="xs")
    p = Project(input=u,
                keys=[("t", t, key("t")),
                      ("d", n_feat, add(mul(key("c"), const(csx)),
                                        key("e")))],
                exprs=[("xs", None, col("xs"))])
    plan = GroupAgg(
        input=Join(left=p, right=Scan("wc", wcol.schema()),
                   on=[("d", key("d"))]),
        group_keys=["t", "c"],
        aggs=[("o", "SUM", mul(col("xs"), col("chunk")))])
    out = execute(plan, {"x": xd, "wc": wcol})
    got = np.asarray(out.cols["o"])            # [t, n_out, cs_col]
    got = got.reshape(t, n_out * cs_col)[:, :m]
    np.testing.assert_allclose(got, x @ w.T, rtol=1e-4, atol=1e-4)


@settings(**COMMON)
@given(rows=st.integers(1, 6), width=st.integers(1, 30),
       cs1=st.integers(1, 8), cs2=st.integers(1, 9))
def test_rechunk_table_roundtrip_any_sizes(rows, width, cs1, cs2):
    """Executor re-chunk helper: chunked@cs1 → re-chunked@cs2 preserves the
    true payload exactly and zero-fills the new tail (padding invariant of
    the planner's per-table chunk-size decisions)."""
    from repro.core.executor import rechunk_chunked_table, table_from_chunked
    x = np.random.default_rng(rows * 31 + width).standard_normal(
        (rows, width)).astype(np.float32)
    ct = ChunkedTensor.from_dense("t", x, chunk_size=cs1)
    t = table_from_chunked(ct)
    r = rechunk_chunked_table(t, cs2, true_width=width)
    n2 = r.keys[-1][1]
    assert (n2 - 1) * cs2 < width <= n2 * cs2  # padding invariant
    flat = np.asarray(r.cols["chunk"]).reshape(rows, n2 * cs2)
    np.testing.assert_array_equal(flat[:, :width], x)
    np.testing.assert_array_equal(flat[:, width:], 0)


@settings(**COMMON)
@given(budget_items=st.integers(1, 5), n_weights=st.integers(2, 10),
       seed=st.integers(0, 99))
def test_pager_budget_invariant(budget_items, n_weights, seed):
    """The hot set never exceeds the budget when every tensor fits it."""
    item = 1024 * 4  # 1024 f32
    pager = WeightPager(budget_bytes=budget_items * item)
    for i in range(n_weights):
        pager.add(f"w{i}", np.zeros(1024, np.float32))
    rng = np.random.default_rng(seed)
    for _ in range(50):
        pager.get(f"w{rng.integers(n_weights)}")
        assert pager.held_bytes <= budget_items * item
    s = pager.stats
    assert s.hits + s.misses == 50


@settings(**COMMON)
@given(n=st.integers(1, 30), k=st.integers(1, 4), e=st.sampled_from([4, 8]))
def test_moe_gates_normalised(n, k, e):
    import jax
    from repro.configs import get_config
    import dataclasses
    from repro.models.moe import moe_init, moe_apply
    cfg = dataclasses.replace(get_config("olmoe-1b-7b", tiny=True),
                              n_experts=e, top_k=min(k, e),
                              capacity_factor=float(e))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, n, cfg.d_model))
    y = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y, np.float32)))


@settings(**COMMON)
@given(steps=st.integers(1, 5), seed=st.integers(0, 10))
def test_data_pipeline_deterministic_resume(steps, seed):
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(vocab=97, seq_len=16, global_batch=4, seed=seed)
    a = src.batch_at(steps)
    b = src.batch_at(steps)  # re-read after "restart"
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards partition the batch deterministically
    s0 = SyntheticLM(vocab=97, seq_len=16, global_batch=4, seed=seed,
                     n_shards=2, shard=0).batch_at(steps)
    s1 = SyntheticLM(vocab=97, seq_len=16, global_batch=4, seed=seed,
                     n_shards=2, shard=1).batch_at(steps)
    assert s0["tokens"].shape == (2, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
