"""Observability layer (ISSUE 6): metrics registry, span tracing, DuckDB
profile parsing/attribution (against checked-in fixtures — no duckdb
import), drift reporting, statement provenance, and the plan-feedback
calibration source.  The duckdb-gated live-profile test rides in
``test_duckdb_e2e.py``."""

import json
import logging
import os
import sqlite3
import threading
import warnings

import numpy as np
import pytest

from repro.core.graph import infer_shapes
from repro.core.llama_graph import (LlamaSpec, build_decode_graph,
                                    convert_weights, empty_cache_tables,
                                    init_llama_params, rope_freq_table,
                                    token_table)
from repro.core.opmap import op_map
from repro.core.passes import postoptimize, preoptimize
from repro.core.pipeline import run_pipeline
from repro.core.sqlgen import generate_sql, generate_sql_with_provenance
from repro.obs import (MetricsRegistry, TraceRecorder, attribute_statement,
                       coverage, drift_report, flatten_profile,
                       parse_profile, run_timed, set_event_registry,
                       split_statements, substitute_params)
from repro.obs.dbtrace import TickTrace
from repro.obs.profile import classify_operator, scanned_table
from repro.planner.calibrate import (fit_from_step_timings,
                                     pipeline_features, step_features)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")

SPEC = LlamaSpec(vocab=16, d_model=8, n_layers=1, n_heads=2, n_kv=1,
                 d_ff=16, rope_theta=10000.0)
CS = 4


def _decode_pipe(**post_kw):
    g = build_decode_graph(SPEC, cache_len=4)
    infer_shapes(g)
    preoptimize(g)
    pipe = op_map(g, chunk_size=CS)
    postoptimize(pipe, **post_kw)
    return pipe


class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        r = MetricsRegistry()
        r.counter("reqs_total").inc()
        r.counter("reqs_total").inc(2)
        assert r.counter("reqs_total").value == 3
        with pytest.raises(ValueError):
            r.counter("reqs_total").inc(-1)
        r.gauge("occupancy").set(0.5)
        r.gauge("occupancy").inc(0.25)
        assert r.gauge("occupancy").value == 0.75
        h = r.histogram("lat_seconds")
        for v in (0.001, 0.002, 0.004, 0.1):
            h.observe(v)
        assert h.count == 4 and h.sum == pytest.approx(0.107)
        assert 0.001 <= h.percentile(50) <= 0.004
        assert h.mean == pytest.approx(0.107 / 4)

    def test_labels_create_separate_series(self):
        r = MetricsRegistry()
        r.counter("cache_total", outcome="hit").inc(3)
        r.counter("cache_total", outcome="miss").inc()
        assert r.counter("cache_total", outcome="hit").value == 3
        assert r.counter("cache_total", outcome="miss").value == 1

    def test_kind_conflict_rejected(self):
        r = MetricsRegistry()
        r.counter("x_total")
        with pytest.raises(ValueError):
            r.gauge("x_total")

    def test_prometheus_exposition(self):
        r = MetricsRegistry()
        r.counter("hits_total", "cache hits", cache="plan").inc(5)
        r.histogram("tick_seconds", "tick latency").observe(0.003)
        text = r.render_prometheus()
        assert '# TYPE hits_total counter' in text
        assert 'hits_total{cache="plan"} 5' in text
        assert '# TYPE tick_seconds histogram' in text
        # cumulative buckets: every bound >= 0.003 counts the observation
        assert 'tick_seconds_bucket{le="0.005"} 1' in text
        assert 'tick_seconds_bucket{le="0.001"} 0' in text
        assert 'tick_seconds_bucket{le="+Inf"} 1' in text
        assert 'tick_seconds_count 1' in text

    def test_histogram_exemplars_track_buckets(self):
        r = MetricsRegistry()
        h = r.histogram("ttft_seconds", buckets=(0.01, 0.1, 1.0))
        h.observe(0.005, exemplar="aaa0")
        h.observe(0.05, exemplar="bbb1")
        h.observe(0.06, exemplar="ccc2")   # same bucket: last wins
        h.observe(5.0, exemplar="ddd3")    # above every bound: +Inf
        h.observe(0.5)                     # no exemplar: leaves none
        assert h.exemplars[0][0] == "aaa0"
        assert h.exemplars[1][0] == "ccc2"
        assert h.exemplars[len(h.bounds)][0] == "ddd3"
        assert 2 not in h.exemplars

    def test_openmetrics_render_carries_exemplars_and_eof(self):
        r = MetricsRegistry()
        r.counter("reqs_total", "requests").inc(2)
        h = r.histogram("ttft_seconds", buckets=(0.01, 1.0))
        h.observe(0.005, exemplar="cafe1234")
        om = r.render_openmetrics()
        assert om.endswith("# EOF\n")
        line = next(l for l in om.splitlines()
                    if l.startswith('ttft_seconds_bucket{le="0.01"}'))
        assert '# {trace_id="cafe1234"} 0.005' in line
        # buckets without an exemplar render bare
        bare = next(l for l in om.splitlines()
                    if l.startswith('ttft_seconds_bucket{le="1.0"}'))
        assert "#" not in bare
        # the classic exposition stays exemplar- and EOF-free
        prom = r.render_prometheus()
        assert "cafe1234" not in prom and "# EOF" not in prom
        # ... and the series names line up between the two renders
        def names(text):
            return {l.split("{")[0].split()[0] for l in text.splitlines()
                    if l and not l.startswith("#")}
        assert names(prom) == names(om)

    def test_exemplars_survive_merge(self):
        parent = MetricsRegistry()
        child = MetricsRegistry()
        child.histogram("lat_seconds").observe(0.003, exemplar="feed1")
        parent.merge(child, shard=0)
        merged = parent.histogram("lat_seconds", shard="0")
        assert any(tid == "feed1"
                   for tid, _, _ in merged.exemplars.values())

    def test_json_dump_roundtrips(self, tmp_path):
        r = MetricsRegistry()
        r.histogram("h_seconds").observe(0.01)
        r.histogram("empty_seconds")  # no observations: no percentiles
        path = str(tmp_path / "metrics.json")
        r.save_json(path)
        with open(path) as f:
            dump = json.load(f)
        assert dump["h_seconds"][0]["p50"] == pytest.approx(0.01)
        assert "p50" not in dump["empty_seconds"][0]


class TestMetricsConcurrency:
    """Regression: the sharded worker pool mutates shared series from N
    threads; unlocked ``+=`` read-modify-writes drop increments."""

    def test_concurrent_writers_keep_exact_totals(self):
        r = MetricsRegistry()
        threads_n, iters = 8, 400
        barrier = threading.Barrier(threads_n)

        def hammer():
            barrier.wait()  # maximise interleaving
            for _ in range(iters):
                # re-fetch through the registry each time: the lookup
                # path (get-or-create under the registry lock) is part
                # of what the worker threads exercise
                r.counter("stress_total").inc()
                r.counter("stress_total", shard="x").inc(2)
                r.gauge("stress_gauge").inc(0.5)
                r.histogram("stress_seconds").observe(0.001)

        ts = [threading.Thread(target=hammer) for _ in range(threads_n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        total = threads_n * iters
        assert r.counter("stress_total").value == total
        assert r.counter("stress_total", shard="x").value == 2 * total
        assert r.gauge("stress_gauge").value == pytest.approx(0.5 * total)
        h = r.histogram("stress_seconds")
        assert h.count == total
        assert h.sum == pytest.approx(0.001 * total)
        # cumulative buckets stayed consistent under contention
        assert h.bucket_counts[h.bounds.index(0.001)] == total

    def test_merge_relabels_and_adds(self):
        parent = MetricsRegistry()
        parent.counter("ticks_total").inc(5)
        for i in range(2):
            child = MetricsRegistry()
            child.counter("ticks_total").inc(10 * (i + 1))
            child.gauge("busy_frac").set(0.25 * (i + 1))
            child.histogram("lat_seconds").observe(0.002 * (i + 1))
            parent.merge(child, shard=i)
        # the parent's own unlabelled series is untouched …
        assert parent.counter("ticks_total").value == 5
        # … and each child landed under its shard label
        assert parent.counter("ticks_total", shard="0").value == 10
        assert parent.counter("ticks_total", shard="1").value == 20
        assert parent.gauge("busy_frac", shard="1").value == 0.5
        h0 = parent.histogram("lat_seconds", shard="0")
        assert h0.count == 1 and h0.percentile(50) == \
            pytest.approx(0.002)
        # merging is additive: a second merge doubles the counter
        child = MetricsRegistry()
        child.counter("ticks_total").inc(10)
        parent.merge(child, shard="0")
        assert parent.counter("ticks_total", shard="0").value == 20

    def test_merge_rejects_mismatched_histogram_bounds(self):
        parent = MetricsRegistry()
        parent.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
        child = MetricsRegistry()
        child.histogram("lat_seconds", buckets=(0.2, 2.0)).observe(0.05)
        with pytest.raises(ValueError):
            parent.merge(child)


class TestTraceRecorder:
    def test_span_nesting_and_chrome_export(self):
        t = [0.0]

        def clock():
            t[0] += 0.001
            return t[0]

        rec = TraceRecorder(clock=clock)
        with rec.span("outer", cat="step"):
            with rec.span("inner", cat="op"):
                pass
        assert {e.name: e.depth for e in rec.events} == \
            {"outer": 0, "inner": 1}
        chrome = rec.to_chrome()
        assert all(ev["ph"] == "X" for ev in chrome["traceEvents"])
        # events sorted by start time: outer opened first
        assert chrome["traceEvents"][0]["name"] == "outer"

    def test_step_times_sum_per_name(self):
        rec = TraceRecorder()
        rec.add_span("a", "step", 0, 100)
        rec.add_span("a", "step", 200, 50)
        rec.add_span("b", "step", 300, 10)
        rec.add_span("x", "op", 0, 999)
        assert rec.step_times_us() == {"a": 150.0, "b": 10.0}
        assert rec.total_us("op") == 999.0


class TestProfileParser:
    @pytest.mark.parametrize("fixture,total", [
        ("duckdb_profile_legacy.json", 0.0042),
        ("duckdb_profile_modern.json", 0.0051),
    ])
    def test_both_key_formats_normalise(self, fixture, total):
        with open(os.path.join(FIXTURES, fixture)) as f:
            root = parse_profile(f.read())
        assert root.operator == "QUERY"
        assert root.timing_s == pytest.approx(total)
        ops = {n.operator for n in flatten_profile(root)}
        assert {"PROJECTION", "HASH_JOIN", "HASH_GROUP_BY"} <= ops
        scans = [n for n in flatten_profile(root)
                 if classify_operator(n.operator) == "scan"]
        assert {scanned_table(n) for n in scans} == {"W__col", "x_embed"}

    def test_bare_operator_tree_gets_query_root(self):
        root = parse_profile({"name": "PROJECTION", "timing": 0.1,
                              "cardinality": 1, "children": []})
        assert root.operator == "QUERY" and len(root.children) == 1

    def test_classify_refines_by_provenance(self):
        class Prov:
            kind = "append"
            quantised = ("lm_head",)
        assert classify_operator("PROJECTION") == "project"
        assert classify_operator("PROJECTION", Prov()) == "dequant_project"
        assert classify_operator("INSERT", Prov()) == "cache_append"
        assert classify_operator("TOTALLY_NEW_OP") == "other"

    def test_attribution_and_coverage(self):
        with open(os.path.join(FIXTURES,
                               "duckdb_profile_modern.json")) as f:
            root = parse_profile(f.read())

        class Prov:
            kind = "bind"
            step = "linear_1"
            quantised = ()
        attributed = attribute_statement(root, Prov())
        assert all(a.step == "linear_1" for a in attributed)
        # all operator time lands on a named step → full coverage
        assert coverage(attributed) == pytest.approx(1.0)
        # against a larger external wall clock, coverage drops
        assert coverage(attributed, total_s=1.0) < 0.01
        # unattributed statements (step=None) dilute coverage
        class NoStep:
            kind = "ddl"
            step = None
            quantised = ()
        mixed = attributed + attribute_statement(root, NoStep())
        assert coverage(mixed) == pytest.approx(0.5)


class TestStatementProvenance:
    def test_provenance_matches_plain_generate(self):
        pipe = _decode_pipe(layout_mode="col", cache_mode="auto")
        sql = generate_sql(pipe, dialect="duckdb", include_conversion=True)
        pairs = generate_sql_with_provenance(pipe, dialect="duckdb",
                                             include_conversion=True)
        assert sql == "\n\n".join(s for s, _ in pairs)

    def test_bind_steps_named_like_pipeline_steps(self):
        pipe = _decode_pipe(layout_mode="col", cache_mode="auto")
        pairs = generate_sql_with_provenance(pipe, dialect="duckdb")
        tagged = {p.step for _, p in pairs if p.kind in ("bind", "append")}
        assert tagged == {s.name for s in pipe.steps}
        binds = [p for _, p in pairs if p.kind == "bind"]
        assert all("scan" in p.ops for p in binds)
        appends = [p for _, p in pairs if p.kind == "append"]
        assert appends and all("cache_append" in p.ops for p in appends)

    def test_quantised_tables_tagged(self):
        pipe = _decode_pipe(precision_mode="int8")
        pairs = generate_sql_with_provenance(pipe, dialect="duckdb",
                                             include_conversion=True)
        quant_binds = [p for _, p in pairs
                       if p.kind == "bind" and p.quantised]
        assert quant_binds  # the dequant projections scan __int8 tables
        assert all(t.endswith("__int8")
                   for p in quant_binds for t in p.quantised)

    def test_table_mode_materialises_steps(self):
        pipe = _decode_pipe(layout_mode="col", cache_mode="auto")
        pairs = generate_sql_with_provenance(pipe, dialect="duckdb",
                                             step_create="TABLE")
        binds = [s for s, p in pairs if p.kind == "bind"]
        assert binds
        assert all(s.lstrip().startswith("CREATE OR REPLACE TABLE")
                   for s in binds)
        # default stays VIEW — golden snapshots elsewhere depend on it
        views = [s for s, p in generate_sql_with_provenance(
            pipe, dialect="duckdb") if p.kind == "bind"]
        assert all(s.lstrip().startswith("CREATE OR REPLACE VIEW")
                   for s in views)


class TestDbTraceSqlite:
    """Engine-independent pieces of dbtrace, driven through SQLite."""

    def test_split_statements_drops_comments(self):
        stmts = split_statements(
            "-- planner annotation\nCREATE TABLE t (a INT);\n"
            "-- another\nINSERT INTO t VALUES (1);")
        assert stmts == ["CREATE TABLE t (a INT);",
                         "INSERT INTO t VALUES (1);"]

    def test_substitute_params_word_boundary(self):
        out = substitute_params("p = :pos AND q = :pos2",
                                {"pos": 3, "pos2": 9})
        assert out == "p = 3 AND q = 9"

    def test_run_timed_attributes_statement_wall_time(self):
        class Prov:
            kind = "bind"
            step = "s1"
            tables = ("t",)
            ops = ("scan",)
            quantised = ()
        con = sqlite3.connect(":memory:")
        tick = run_timed(con, [
            ("CREATE TABLE t (a INT);\nINSERT INTO t VALUES (1), (2);",
             Prov()),
            ("SELECT COUNT(*) FROM t WHERE a > :lo;", Prov()),
        ], params={"lo": 0})
        assert len(tick.statements) == 3
        assert tick.coverage() == pytest.approx(1.0)
        assert set(tick.step_times_us()) == {"s1"}
        assert tick.step_times_us()["s1"] > 0
        # the SELECT's wall time was split over its EXPLAIN QUERY PLAN
        # rows (scan); the DDL statements fell back to one
        # op_class="statement" record each
        classes = tick.class_times_us()
        assert set(classes) == {"statement", "scan"}
        assert sum(classes.values()) == pytest.approx(tick.wall_s * 1e6)

    def test_run_timed_eqp_join_surfaces_operator_structure(self):
        class Prov:
            kind = "bind"
            step = "join_step"
            tables = ("w", "x")
            ops = ("join",)
            quantised = ()
        con = sqlite3.connect(":memory:")
        con.execute("CREATE TABLE w (k INT PRIMARY KEY, v REAL)")
        con.execute("CREATE TABLE x (k INT, v REAL)")
        con.executemany("INSERT INTO w VALUES (?, ?)",
                        [(i, float(i)) for i in range(8)])
        con.executemany("INSERT INTO x VALUES (?, ?)",
                        [(i % 4, 1.0) for i in range(16)])
        tick = run_timed(con, [(
            "SELECT w.k, SUM(w.v * x.v) FROM w JOIN x ON w.k = x.k "
            "GROUP BY w.k ORDER BY w.k;", Prov())])
        ops = tick.attributed
        assert all(a.step == "join_step" for a in ops)
        # SQLite's nested-loop join: first table term is the outer
        # scan, the second (same parent) is the join inner loop
        classes = {a.op_class for a in ops}
        assert "join" in classes
        assert classes & {"scan", "search"}
        tables = {a.table for a in ops if a.table}
        assert tables <= {"w", "x"} and len(tables) == 2
        # uniform split keeps the per-step total exact
        assert tick.step_times_us()["join_step"] == \
            pytest.approx(tick.wall_s * 1e6)
        assert tick.coverage() == pytest.approx(1.0)

    def test_run_timed_explain_off_restores_fallback(self):
        class Prov:
            kind = "bind"
            step = "s"
            tables = ()
            ops = ()
            quantised = ()
        con = sqlite3.connect(":memory:")
        con.execute("CREATE TABLE t (a INT)")
        tick = run_timed(con, [("SELECT * FROM t;", Prov())],
                         explain=False)
        assert [a.op_class for a in tick.attributed] == ["statement"]

    def test_classify_eqp_detail_variants(self):
        from repro.obs.profile import classify_eqp_detail
        assert classify_eqp_detail("SCAN t") == ("scan", "SCAN", "t")
        assert classify_eqp_detail("SCAN TABLE t") == ("scan", "SCAN", "t")
        assert classify_eqp_detail(
            "SEARCH w USING INTEGER PRIMARY KEY (rowid=?)",
            first_in_parent=False) == ("join", "SEARCH", "w")
        assert classify_eqp_detail(
            "USE TEMP B-TREE FOR ORDER BY")[0] == "sort"
        assert classify_eqp_detail("")[0] == "other"

    def test_tick_trace_exports(self, tmp_path):
        class Prov:
            kind = "bind"
            step = "s1"
            tables = ()
            ops = ()
            quantised = ()
        con = sqlite3.connect(":memory:")
        tick = run_timed(con, [("SELECT 1;", Prov())])
        chrome = tick.to_recorder().to_chrome()
        cats = {e["cat"] for e in chrome["traceEvents"]}
        assert "statement" in cats
        path = str(tmp_path / "tick.json")
        tick.save_json(path)
        with open(path) as f:
            dump = json.load(f)
        assert dump["coverage"] == pytest.approx(1.0)
        assert dump["statements"][0]["step"] == "s1"


class TestDriftReport:
    def test_on_model_run_has_unit_ratios(self):
        feats = {"a": (100.0, 10.0), "b": (200.0, 40.0), "c": (50.0, 5.0)}
        obs = {s: 2.0 * (r + 1.0 * g) + 7.0 for s, (r, g) in feats.items()}
        rep = drift_report(feats, obs)
        assert rep.scale_us == pytest.approx(2.0)
        assert rep.intercept_us == pytest.approx(7.0)
        assert rep.rms_rel_drift == pytest.approx(0.0, abs=1e-9)
        assert all(s.ratio == pytest.approx(1.0) for s in rep.steps)

    def test_off_model_step_surfaces_as_worst(self):
        feats = {"a": (100.0, 0.0), "b": (100.0, 0.0), "c": (100.0, 0.0),
                 "slow": (100.0, 0.0)}
        obs = {"a": 100.0, "b": 100.0, "c": 100.0, "slow": 400.0}
        rep = drift_report(feats, obs)
        assert rep.worst(1)[0].step == "slow"
        assert rep.rms_rel_drift > 0.3

    def test_unattributed_time_counted(self):
        rep = drift_report({"a": (10.0, 0.0)},
                           {"a": 10.0, "mystery": 90.0})
        assert rep.unattributed_us == pytest.approx(90.0)
        assert rep.total_observed_us == pytest.approx(100.0)

    def test_fixed_scale_measures_absolute_drift(self):
        feats = {"a": (100.0, 0.0), "b": (300.0, 0.0)}
        obs = {"a": 300.0, "b": 900.0}  # 3 µs/unit, calibrated at 1.5
        rep = drift_report(feats, obs, scale_us=1.5)
        assert all(s.ratio == pytest.approx(2.0) for s in rep.steps)

    def test_empty_features_yield_empty_report(self):
        rep = drift_report({}, {"x": 5.0})
        assert rep.steps == [] and rep.rms_rel_drift == 0.0
        assert rep.unattributed_us == pytest.approx(5.0)
        assert rep.total_observed_us == pytest.approx(5.0)

    def test_fully_disjoint_observation_is_all_unattributed(self):
        # the watchdog's worst window: observed step names share nothing
        # with the priced features (e.g. a renamed pipeline)
        rep = drift_report({"a": (10.0, 1.0)}, {"b": 7.0, "c": 3.0})
        assert rep.steps == [] and rep.scale_us == 0.0
        assert rep.unattributed_us == pytest.approx(10.0)

    def test_zero_unit_step_gets_inf_ratio_not_crash(self):
        # a lone step priced at zero cost units: the fitted prediction
        # is 0 µs, the ratio degrades to inf and drops out of the RMS
        rep = drift_report({"z": (0.0, 0.0)}, {"z": 4.0})
        assert rep.steps[0].ratio == float("inf")
        assert rep.rms_rel_drift == 0.0

    def test_zero_observed_times_fit_zero_scale(self):
        rep = drift_report({"a": (10.0, 0.0), "b": (20.0, 0.0)},
                           {"a": 0.0, "b": 0.0})
        assert rep.scale_us == 0.0
        assert all(s.ratio == float("inf") for s in rep.steps)
        assert rep.rms_rel_drift == 0.0


class TestTracedRunPipeline:
    def test_step_spans_cover_all_steps(self):
        pipe = _decode_pipe(layout_mode="col", cache_mode="auto")
        params = init_llama_params(SPEC, seed=0)
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC, 4, chunk_size=CS))
        env["token_ids"] = token_table(np.asarray([5], np.int32))
        env["freq_each_token"] = rope_freq_table(
            np.asarray([0]), SPEC.head_dim, SPEC.rope_theta)
        tracer = TraceRecorder()
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0},
                               tracer=tracer)
        ref, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        np.testing.assert_allclose(
            np.asarray(outs["logits"].cols["v"]),
            np.asarray(ref["logits"].cols["v"]), rtol=1e-6)
        times = tracer.step_times_us()
        assert set(times) == {s.name for s in pipe.steps}
        assert all(t > 0 for t in times.values())
        # executor op sub-spans nest under the step spans
        op_events = [e for e in tracer.events if e.cat == "op"]
        assert op_events and all(e.depth >= 1 for e in op_events)


class TestCalibrationFeedback:
    def test_step_features_sum_to_pipeline_features(self):
        feats = step_features(SPEC, "decode", 1, CS, "col", cache_len=4)
        assert feats  # matmul sites were priced
        assert pipeline_features(SPEC, "decode", 1, CS, "col",
                                 cache_len=4) == (
            sum(r for r, _ in feats.values()),
            sum(g for _, g in feats.values()))

    def test_fit_recovers_synthetic_group_weight(self):
        feats = step_features(SPEC, "decode", 1, CS, "col", cache_len=4)
        obs = {s: 3.0 * (r + 2.5 * g) + 11.0
               for s, (r, g) in feats.items()}
        fit = fit_from_step_timings(feats, obs)
        assert fit.params.group_weight == pytest.approx(2.5, rel=1e-6)
        assert fit.scale_us == pytest.approx(3.0, rel=1e-6)
        assert fit.n_points == len(feats)

    def test_underdetermined_fit_emits_fallback_event(self, caplog):
        reg = MetricsRegistry()
        set_event_registry(reg)
        try:
            with warnings.catch_warnings(), \
                    caplog.at_level(logging.WARNING, logger="repro.obs"):
                warnings.simplefilter("ignore")
                fit = fit_from_step_timings({"a": (1.0, 1.0)}, {"a": 5.0})
        finally:
            set_event_registry(None)
        from repro.planner.cost import CostParams
        assert fit.params.group_weight == CostParams().group_weight
        assert any("calibration_fallback" in r.getMessage()
                   for r in caplog.records)
        dump = reg.to_dict()["obs_events_total"]
        assert dump[0]["labels"] == {"event": "calibration_fallback"}
        assert dump[0]["value"] == 1.0


class TestServingMetricsSmoke:
    def test_engine_records_metrics_and_traces(self):
        params = init_llama_params(SPEC, seed=0)
        from repro.serving.engine import RelationalEngine
        reg = MetricsRegistry()
        tracer = TraceRecorder()
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               metrics=reg, tracer=tracer)
        eng.generate([3, 5], max_new_tokens=3)
        dump = reg.to_dict()
        assert dump["engine_decode_step_seconds"][0]["count"] == 2
        plan_lookups = {tuple(sorted(e["labels"].items())): e["value"]
                        for e in dump["engine_plan_cache_total"]}
        assert plan_lookups[(("cache", "prefill"),
                             ("outcome", "miss"))] == 1.0
        # every prefill + decode step span is on the trace
        assert tracer.step_times_us()
        # disabled observability leaves no trace of itself
        eng2 = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8)
        assert eng2.metrics is None and eng2.tracer is None

    def test_scheduler_metrics(self):
        from repro.serving.kvcache import PagedKVCache, PagedKVConfig
        from repro.serving.scheduler import ContinuousBatcher, Request
        cfg = PagedKVConfig(n_layers=1, n_kv=1, head_dim=4, page_size=4,
                            n_pages=16, max_pages_per_seq=4)
        kv = PagedKVCache(cfg, max_seqs=4)
        reg = MetricsRegistry()

        def prefill(req, seq_id):
            kv.ensure_capacity(seq_id, len(req.prompt))
            return 1

        sched = ContinuousBatcher(kv, prefill,
                                  lambda ids, toks: [2] * len(ids),
                                  max_batch=2, metrics=reg)
        for r in range(3):
            sched.submit(Request(rid=r, prompt=[1, 2], max_new_tokens=2))
        done = sched.run()
        assert len(done) == 3
        dump = reg.to_dict()
        assert dump["serving_ttft_seconds"][0]["count"] == 3
        assert dump["serving_completed_total"][0]["value"] == 3.0
        assert dump["serving_tick_seconds"][0]["count"] == \
            sched.stats.decode_steps
        assert 0 < reg.gauge("serving_batch_occupancy").value <= 1.0

    def test_pager_metrics_mirror_stats(self, tmp_path):
        from repro.serving.pager import WeightPager
        reg = MetricsRegistry()
        pager = WeightPager(64, policy="clock", metrics=reg)
        pager.add("a", np.zeros(8, np.float32))   # 32 B
        pager.add("b", np.zeros(8, np.float32))
        pager.add("c", np.zeros(8, np.float32))
        pager.get("a"); pager.get("b"); pager.get("a")  # hit
        pager.get("c")                                  # evicts
        assert reg.counter("pager_hits_total").value == pager.stats.hits
        assert reg.counter("pager_misses_total").value == \
            pager.stats.misses
        assert reg.counter("pager_evictions_total").value == \
            pager.stats.evictions > 0
        assert reg.gauge("pager_held_bytes").value == pager.held_bytes


class TestBenchmarkMetadata:
    def test_run_metadata_stamp(self):
        common = pytest.importorskip("benchmarks.common")
        payload = common.stamp({"results": []})
        meta = payload["run_metadata"]
        assert {"timestamp_utc", "python", "cpu_count",
                "duckdb"} <= set(meta)
        json.dumps(payload)  # JSON-serialisable
