"""Quantised chunk payloads (ISSUE 5): codec kernels and error bounds,
precision planning (eligibility, forced/auto/budget modes, pool pinning),
executor equivalence within codec tolerance, golden SQL snapshots for the
quantised DDL + dequant projections (both dialects), and the engine knob
(in-memory, paged, auto-under-budget, accuracy gate)."""

import numpy as np
import pytest

from repro.core.chunked import ChunkedTensor
from repro.core.executor import table_from_chunked
from repro.core.graph import Graph, infer_shapes
from repro.core.llama_graph import (LlamaSpec, build_decode_graph,
                                    build_prefill_graph, convert_weights,
                                    empty_cache_tables, init_llama_params,
                                    rope_freq_table, token_table)
from repro.core.opmap import op_map
from repro.core.passes import postoptimize, preoptimize
from repro.core.pipeline import run_pipeline
from repro.core.sqlgen import generate_sql
from repro.planner import CostParams, ResidencyPool, plan_layouts
from repro.quant import (CODECS, NF4_LEVELS, PRECISIONS, precision_bytes,
                         quant_schema, quantise_chunked_table)

SPEC = LlamaSpec(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=2,
                 d_ff=64, rope_theta=10000.0)


def _linear_pipe(cs=4):
    g = Graph(name="lin")
    g.inputs = ["ids"]
    g.annotate("ids", (("t", 4),))
    g.annotate("vocab", (("tok", 16), ("d", 8)))
    g.initializers["vocab"] = None
    g.initializers["W"] = None
    g.annotate("W", (("j", 8), ("d", 8)))
    x = g.add("embedding", ["vocab", "ids"])
    g.add("linear", [x, "W"], out_features=8, output="y")
    g.outputs = ["y"]
    infer_shapes(g)
    return op_map(g, chunk_size=cs)


@pytest.fixture(scope="module")
def params():
    return init_llama_params(SPEC, seed=0)


class TestCodecs:
    @pytest.mark.parametrize("name", list(CODECS))
    def test_roundtrip_within_bound(self, name):
        codec = CODECS[name]
        x = np.random.default_rng(0).standard_normal((6, 3, 16)).astype(
            np.float32)
        codes, scales = codec.quantise(x)
        y = np.asarray(codec.dequantise(codes, scales))
        bound = np.asarray(codec.roundtrip_bound(scales))[..., None]
        assert np.all(np.abs(y - x) <= bound + 1e-7)

    @pytest.mark.parametrize("name", list(CODECS))
    def test_pack_unpack_inverse(self, name):
        codec = CODECS[name]
        x = np.random.default_rng(1).standard_normal((5, 2, 8)).astype(
            np.float32)
        codes, _ = codec.quantise(x)
        packed = codec.pack(np.asarray(codes))
        if name == "nf4":  # two codes per byte
            assert packed.dtype == np.uint8 and packed.shape[-1] == 4
        np.testing.assert_array_equal(np.asarray(codec.unpack(packed, 8)),
                                      np.asarray(codes))

    def test_int8_codes_in_range(self):
        codec = CODECS["int8"]
        x = np.random.default_rng(2).standard_normal((4, 32)).astype(
            np.float32) * 10
        codes, _ = codec.quantise(x)
        assert np.asarray(codes).dtype == np.int8
        assert np.abs(np.asarray(codes)).max() <= 127

    def test_nf4_codebook_exact_on_levels(self):
        """Values exactly on NF4 levels (× a scale) round-trip exactly."""
        codec = CODECS["nf4"]
        x = 3.25 * np.asarray(NF4_LEVELS, np.float32).reshape(1, 16)
        codes, scales = codec.quantise(x)
        np.testing.assert_array_equal(np.asarray(codes)[0], np.arange(16))
        np.testing.assert_allclose(
            np.asarray(codec.dequantise(codes, scales)), x, rtol=1e-6)

    def test_zero_chunk_is_safe(self):
        for codec in CODECS.values():
            codes, scales = codec.quantise(np.zeros((2, 4), np.float32))
            y = np.asarray(codec.dequantise(codes, scales))
            np.testing.assert_array_equal(y, 0.0)

    def test_precision_bytes_model(self):
        # 1024 elements in 128 groups of 8
        assert precision_bytes("f32", 1024, 128) == 4096
        assert precision_bytes("int8", 1024, 128) == 1024 + 512
        assert precision_bytes("nf4", 1024, 128) == 512 + 512

    def test_quantise_chunked_table_schema(self):
        w = np.random.default_rng(3).standard_normal((8, 12)).astype(
            np.float32)
        t = table_from_chunked(ChunkedTensor.from_dense("w", w,
                                                        chunk_size=4))
        q = quantise_chunked_table(t, CODECS["int8"])
        assert set(q.cols) == {"qchunk", "scale"}
        assert q.keys == t.keys
        qs = quant_schema(t.schema("w"))
        assert qs.col_names == ("qchunk", "scale")


class TestPrecisionPlanning:
    def test_eligibility(self):
        """Matmul weights AND the embedding value-join table quantise;
        norms and input tables never do."""
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="off", precision_mode="int8")
        tables = {d.table for d in plan.precision_decisions}
        assert "vocabulary" in tables and "lm_head" in tables
        assert "o_weights_L0" in tables and "GLU_W2_L1" in tables
        assert not any("Norm" in t for t in tables)
        assert not any(t in ("freq_each_token", "token_ids")
                       for t in tables)
        # the quantised twins took over the weight schemas
        assert "lm_head__int8" in pipe.weight_schemas
        assert "lm_head" not in pipe.weight_schemas
        assert pipe.table_precisions["lm_head__int8"] == "int8"

    def test_auto_unbounded_keeps_f32(self):
        """Under the analytic defaults with no budget pressure, f32 wins
        (quantisation is not free: the dequant term)."""
        g = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto", precision_mode="auto")
        assert plan.precision_decisions == []

    def test_auto_budget_quantises_biggest_first(self):
        """The residency pass flips tables by bytes saved until the
        working set fits the pool budget."""
        g = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        # f32 weights of the 2-layer spec are ~120 KB; a 60 KB budget
        # forces roughly half the bytes out
        pool = ResidencyPool(budget_bytes=60_000)
        plan = plan_layouts(pipe, mode="off", pool=pool,
                            precision_mode="auto")
        assert plan.precision_decisions
        assert all(d.budget_driven for d in plan.precision_decisions)
        assert all(d.precision == "int8" for d in plan.precision_decisions)
        # the flips really reclaim bytes: every decision shrinks its table
        assert all(d.q_bytes < d.f32_bytes
                   for d in plan.precision_decisions)

    def test_auto_budget_escalates_to_nf4(self):
        """A budget int8 alone cannot satisfy escalates to nf4."""
        g = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        pool = ResidencyPool(budget_bytes=1)  # nothing fits: max compression
        plan = plan_layouts(pipe, mode="off", pool=pool,
                            precision_mode="auto")
        assert plan.precision_decisions
        assert all(d.precision == "nf4" for d in plan.precision_decisions)

    def test_table_precision_overrides(self):
        g = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="off", precision_mode="int8",
                            table_precisions={"lm_head": "f32",
                                              "vocabulary": "nf4"})
        by = {d.table: d.precision for d in plan.precision_decisions}
        assert "lm_head" not in by           # exempted
        assert by["vocabulary"] == "nf4"     # overridden codec
        assert by["o_weights_L0"] == "int8"  # mode applies elsewhere

    def test_unknown_precision_rejected(self):
        g = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        with pytest.raises(ValueError, match="unknown precision"):
            plan_layouts(pipe, mode="off", precision_mode="auto",
                         table_precisions={"lm_head": "fp8"})
        g2 = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g2)
        pipe2 = op_map(g2, chunk_size=8)
        with pytest.raises(ValueError, match="precision mode"):
            plan_layouts(pipe2, mode="off", precision_mode="int4")

    def test_pool_pins_precisions_across_plans(self):
        """Two pipelines over one pool must agree on every shared table's
        payload format — including tables the first plan kept f32."""
        pool = ResidencyPool(budget_bytes=60_000)

        def plan(kind):
            g = (build_prefill_graph(SPEC, 4) if kind == "prefill"
                 else build_decode_graph(SPEC, cache_len=8))
            infer_shapes(g)
            pipe = op_map(g, chunk_size=8)
            plan_layouts(pipe, mode="off", pool=pool,
                         precision_mode="auto")
            return pipe

        dec = plan("decode")
        pre = plan("prefill")
        dprec = dict(dec.table_precisions)
        pprec = dict(pre.table_precisions)
        assert dprec  # the budget really quantised something
        assert dprec == pprec  # identical table sets -> identical choices
        # pinned entries include the f32 keeps
        assert any(p == "f32" for p in pool.precisions.values()) or \
            len(pool.precisions) == len(dprec)

    def test_precision_cost_model_shape(self):
        """f32 wins at the analytic defaults; int8 wins once bytes are
        expensive; the codec dequant multiplier orders int8 before nf4
        at moderate byte pressure."""
        from repro.planner import choose_precision, precision_cost
        p = CostParams()
        best, costs = choose_precision(64 * 64, 64 * 8, p)
        assert best == "f32"
        expensive = CostParams(byte_weight=2.0, dequant_weight=0.25)
        best2, costs2 = choose_precision(64 * 64, 64 * 8, expensive)
        assert best2 != "f32"
        assert precision_cost("int8", 4096, 512, p) < \
            precision_cost("nf4", 4096, 512, p)


class TestExecutorEquivalence:
    def _prefill(self, params, ids, mode, precision, cs=8):
        g = build_prefill_graph(SPEC, len(ids))
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=cs)
        postoptimize(pipe, layout_mode=mode, precision_mode=precision)
        env = convert_weights(params, chunk_size=cs)
        env.update(empty_cache_tables(SPEC, len(ids), chunk_size=cs))
        env["token_ids"] = token_table(ids)
        env["freq_each_token"] = rope_freq_table(
            np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        return np.asarray(outs["logits"].cols["v"]).reshape(
            len(ids), -1)[:, : SPEC.vocab]

    @pytest.mark.parametrize("mode", ["off", "auto", "col"])
    @pytest.mark.parametrize("precision,tol", [("int8", 0.35),
                                               ("nf4", 2.5)])
    def test_prefill_logits_within_codec_tolerance(self, params, mode,
                                                   precision, tol):
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        ref = self._prefill(params, ids, mode, "off")
        got = self._prefill(params, ids, mode, precision)
        err = np.abs(got - ref).max()
        assert err <= tol, (mode, precision, err)
        assert err > 0  # the quantised path really took effect

    def test_decode_kv_cached_quantised(self, params):
        """KV-cached decode with quantised weights tracks the f32 decode
        within int8 tolerance at every step."""
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        MAXT = 9
        outs = {}
        for precision in ("off", "int8"):
            g = build_prefill_graph(SPEC, len(ids), cache_len=MAXT)
            infer_shapes(g)
            preoptimize(g)
            pre = op_map(g, chunk_size=8)
            postoptimize(pre, layout_mode="auto",
                         precision_mode=precision)
            g2 = build_decode_graph(SPEC, cache_len=MAXT)
            infer_shapes(g2)
            preoptimize(g2)
            dec = op_map(g2, chunk_size=8)
            postoptimize(dec, layout_mode="auto",
                         precision_mode=precision)
            env = convert_weights(params, chunk_size=8)
            env.update(empty_cache_tables(SPEC, MAXT, chunk_size=8))
            env["token_ids"] = token_table(ids)
            env["freq_each_token"] = rope_freq_table(
                np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
            _, env = run_pipeline(pre, env, scalars={"cache_position": 0})
            logs, cur = [], len(ids)
            for tok in [21, 33, 7]:
                env["token_ids"] = token_table(np.asarray([tok], np.int32))
                env["freq_each_token"] = rope_freq_table(
                    np.asarray([cur]), SPEC.head_dim, SPEC.rope_theta)
                o, env = run_pipeline(dec, env,
                                      scalars={"cache_position": cur})
                logs.append(np.asarray(o["logits"].cols["v"]).reshape(-1)
                            [: SPEC.vocab])
                cur += 1
            outs[precision] = np.stack(logs)
        err = np.abs(outs["int8"] - outs["off"]).max()
        assert 0 < err <= 0.5

    def test_quantised_matmul_within_analytic_bound(self):
        """The relational quantised matmul's error respects the codec's
        analytic matmul bound (scales × activation L1 mass)."""
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="off", precision_mode="int8")
        rng = np.random.default_rng(0)
        w = {"vocab": rng.standard_normal((16, 8)).astype(np.float32),
             "W": rng.standard_normal((8, 8)).astype(np.float32)}
        env = convert_weights(w, chunk_size=4)
        env["ids"] = token_table(np.asarray([3, 0, 15, 7], np.int32))
        outs, _ = run_pipeline(pipe, env)
        got = np.asarray(outs["y"].cols["v"]).reshape(4, 8)
        codec = CODECS["int8"]
        # reference through the *quantised embedding* (x itself dequants)
        xq = np.asarray(codec.dequantise(*codec.quantise(
            w["vocab"].reshape(16, 2, 4)))).reshape(16, 8)[[3, 0, 15, 7]]
        ref = xq @ w["W"].T
        _, scales = codec.quantise(w["W"].reshape(8, 2, 4))
        bound = np.asarray(codec.matmul_bound(
            scales, xq.reshape(4, 2, 4))).reshape(4, 8)
        assert np.all(np.abs(got - ref) <= bound + 1e-5)


GOLDEN_QUANT_DDL_DUCKDB = """\
-- precision: int8 (planner)
CREATE TABLE W__int8 (j INT32, c INT32, qchunk TINYINT[4], scale FLOAT);"""

GOLDEN_NF4_DDL_DUCKDB = """\
-- precision: nf4 (planner)
CREATE TABLE W__nf4 (j INT32, c INT32, qchunk UTINYINT[4], scale FLOAT);"""

GOLDEN_QUANT_CONVERSION_DUCKDB = """\
-- QUANTISE (int8): W -> W__int8
CREATE OR REPLACE TABLE W__int8 AS
SELECT j, c, list_transform(chunk, x -> CAST(round(x / scale) AS TINYINT)) AS qchunk, scale
FROM (SELECT j, c, chunk, greatest(absmax(chunk), 1e-12) / 127.0 AS scale FROM W);"""

GOLDEN_NF4_CONVERSION_DUCKDB = """\
-- QUANTISE (nf4): W -> W__nf4
CREATE OR REPLACE TABLE W__nf4 AS
SELECT j, c, list_transform(chunk, x -> nf4_encode(x / scale)) AS qchunk, scale
FROM (SELECT j, c, chunk, greatest(absmax(chunk), 1e-12) AS scale FROM W);"""

GOLDEN_QUANT_CONVERSION_ANSI = """\
-- QUANTISE (int8): W -> W__int8
CREATE OR REPLACE TABLE W__int8 AS
SELECT j, c, quantise_int8(chunk, scale) AS qchunk, scale
FROM (SELECT j, c, chunk, greatest(absmax(chunk), 1e-12) / 127.0 AS scale FROM W);"""

# the dequant projection is inlined as a CTE feeding the matmul join
GOLDEN_QUANT_VIEW_DUCKDB = """\
CREATE OR REPLACE VIEW y AS
WITH t6 AS (SELECT j, c, list_transform(qchunk, x -> x * (scale)) AS chunk FROM W__int8),
  t5 AS (SELECT L.t, L.c, R.j, L.v, R.chunk AS chunk FROM embedding_1 AS L JOIN t6 AS R ON R.c = L.c),
  t4 AS (SELECT t, j, SUM(list_dot_product(v, chunk)) AS s FROM t5 GROUP BY t, j),
  t3 AS (SELECT t AS t, (j // 4) AS c, (j % 4) AS e, s AS x FROM t4)
SELECT t, c, collect_as_array(LIST(e), LIST(x)) AS v FROM t3 GROUP BY t, c;"""

GOLDEN_QUANT_VIEW_ANSI = """\
CREATE OR REPLACE VIEW y AS
WITH t6 AS (SELECT j, c, map_vec(qchunk, 'x * (scale)') AS chunk FROM W__int8),
  t5 AS (SELECT L.t, L.c, R.j, L.v, R.chunk AS chunk FROM embedding_1 AS L JOIN t6 AS R ON R.c = L.c),
  t4 AS (SELECT t, j, SUM(dot(v, chunk)) AS s FROM t5 GROUP BY t, j),
  t3 AS (SELECT t AS t, (j / 4) AS c, (j % 4) AS e, s AS x FROM t4)
SELECT t, c, collect_as_array(LIST(e), LIST(x)) AS v FROM t3 GROUP BY t, c;"""

GOLDEN_NF4_VIEW_FRAGMENT_DUCKDB = (
    "SELECT j, c, list_transform(nf4_dequant(qchunk), x -> x * (scale)) "
    "AS chunk FROM W__nf4")


class TestQuantSQLSnapshots:
    """Pinned snapshots: quantised DDL, f32 → quantised conversion and
    the inline dequant projection, both dialects."""

    def _sql(self, dialect, precision="int8"):
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="off", precision_mode=precision)
        return generate_sql(pipe, dialect=dialect, include_conversion=True)

    def test_duckdb_int8_script(self):
        sql = self._sql("duckdb")
        assert GOLDEN_QUANT_DDL_DUCKDB in sql
        assert GOLDEN_QUANT_CONVERSION_DUCKDB in sql
        assert GOLDEN_QUANT_VIEW_DUCKDB in sql
        # the f32 source DDL survives as the conversion input
        assert "CREATE TABLE W (j INT32, c INT32, chunk FLOAT[4]);" in sql
        # the quant UDF prelude ships with the script
        assert "CREATE OR REPLACE MACRO absmax(arr)" in sql
        assert "CREATE OR REPLACE MACRO nf4_encode(v)" in sql

    def test_duckdb_nf4_script(self):
        sql = self._sql("duckdb", precision="nf4")
        assert GOLDEN_NF4_DDL_DUCKDB in sql
        assert GOLDEN_NF4_CONVERSION_DUCKDB in sql
        assert GOLDEN_NF4_VIEW_FRAGMENT_DUCKDB in sql

    def test_ansi_int8_script(self):
        sql = self._sql("ansi")
        assert GOLDEN_QUANT_DDL_DUCKDB in sql  # DDL is dialect-invariant
        assert GOLDEN_QUANT_CONVERSION_ANSI in sql
        assert GOLDEN_QUANT_VIEW_ANSI in sql

    def test_quantised_col_table_chains_conversions(self):
        """A quantised column copy emits ROW2COL first, then the
        quantisation reading the column table."""
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="col", precision_mode="int8")
        sql = generate_sql(pipe, dialect="duckdb", include_conversion=True)
        i_col = sql.find("-- ROW2COL: W -> W__col")
        i_q = sql.find("-- QUANTISE (int8): W__col -> W__col__int8")
        assert 0 <= i_col < i_q
        assert ("-- layout: col_chunk; precision: int8 (planner)\n"
                "CREATE TABLE W__col__int8 (d INT32, c INT32, "
                "qchunk TINYINT[4], scale FLOAT);") in sql
        # the intermediate f32 column table is declared for the chain
        assert "CREATE TABLE W__col (d INT32, c INT32, chunk FLOAT[4]);" \
            in sql

    def test_llama_decode_script_quantised(self, params):
        g = build_decode_graph(SPEC, cache_len=16)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        postoptimize(pipe, layout_mode="off", precision_mode="int8")
        for dialect in ("duckdb", "ansi"):
            sql = generate_sql(pipe, dialect=dialect)
            assert "CREATE TABLE vocabulary__int8" in sql
            assert "CREATE TABLE lm_head__int8" in sql
            assert "JOIN" in sql and "qchunk" in sql


class TestEngineKnob:
    def test_forced_codec_generates(self, params):
        from repro.serving.engine import RelationalEngine
        from repro.quant.gate import logit_error_between
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               precision="f32")
        for precision, tol in (("int8", 0.5), ("nf4", 2.5)):
            eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                                   precision=precision)
            assert len(eng.table_precision_choices) >= SPEC.n_layers * 7
            r = eng.generate(prompt, 4)
            assert len(r.tokens) == 4
            err = logit_error_between(eng, ref, prompt)
            assert 0 < err <= tol

    def test_paged_matches_in_memory(self, params, tmp_path):
        """Quantisation is deterministic: the paged engine (packed cold
        codes, LazyEnv wraps) generates exactly the in-memory quantised
        tokens, with a working set far below f32's."""
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        inm = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               precision="int8")
        pag = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               precision="int8", residency="paged",
                               budget_bytes=1 << 20,
                               disk_dir=str(tmp_path))
        gi = inm.generate(prompt, 4)
        gp = pag.generate(prompt, 4)
        assert gp.tokens == gi.tokens
        f32 = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               precision="f32", residency="paged",
                               budget_bytes=1 << 20,
                               disk_dir=str(tmp_path / "f32"))
        gf = f32.generate(prompt, 4)
        # the paged hot set shrank by more than 2x (int8 payload + scales
        # at the test's tiny chunk size; bigger chunks approach 4x)
        assert gp.peak_working_set * 2 < gf.peak_working_set

    def test_auto_admits_quantised_under_budget(self, params, tmp_path):
        """Acceptance: precision="auto" admits >= 1 quantised table under
        a constrained pager budget, and the engine still generates."""
        from repro.serving.engine import RelationalEngine
        eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               precision="auto", residency="paged",
                               budget_bytes=40_000, disk_dir=str(tmp_path))
        assert len(eng.table_precision_choices) >= 1
        assert len(eng.generate([3, 17, 42], 3).tokens) == 3

    def test_auto_in_memory_keeps_f32(self, params):
        from repro.serving.engine import RelationalEngine
        eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               precision="auto")
        assert eng.table_precision_choices == {}

    def test_accuracy_gate(self, params):
        from repro.serving.engine import RelationalEngine
        from repro.quant.gate import AccuracyBudgetExceeded
        RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                         precision="int8", accuracy_budget=0.5)
        with pytest.raises(AccuracyBudgetExceeded):
            RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                             precision="nf4", accuracy_budget=1e-4)

    def test_batched_decode_with_quantised_weights(self, params):
        """The seq-keyed batched plan runs against the same quantised
        tables (pool-pinned precisions) and matches the sequential
        quantised engine exactly."""
        from repro.serving.engine import RelationalEngine
        eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=24,
                               precision="int8")
        prompts = [[5, 9, 2, 7], [1, 2, 3]]
        refs = [eng.generate(p, max_new_tokens=3).tokens for p in prompts]
        dec = eng.batched_decoder(max_seqs=2)
        toks = [dec.prefill(p, i) for i, p in enumerate(prompts)]
        outs = [[t] for t in toks]
        for _ in range(2):
            nxt = dec.decode([0, 1], [o[-1] for o in outs])
            for o, t in zip(outs, nxt):
                o.append(t)
        for got, ref in zip(outs, refs):
            assert got == ref

    def test_invalid_precision_rejected(self, params):
        from repro.serving.engine import RelationalEngine
        with pytest.raises(AssertionError):
            RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                             precision="fp16")
