"""Sharded relational execution (ISSUE 7 tentpole): shard-planner units
(balanced ranges, site matching, pricing refusal, N=1 bit-identity),
golden per-shard SQL for both combine flavours, worker-pool slice/combine
semantics, engine equivalence across residencies and precisions, and the
merged per-shard observability surface."""

import numpy as np
import pytest

from repro.core.executor import DenseTable
from repro.core.graph import Graph, infer_shapes
from repro.core.llama_graph import (LlamaSpec, build_decode_graph,
                                    init_llama_params)
from repro.core.opmap import op_map
from repro.core.passes import postoptimize, preoptimize
from repro.core import relational as ra
from repro.core.sqlgen import generate_sql
from repro.planner import plan_layouts
from repro.planner.shard import (COMBINE_CONCAT, COMBINE_SUM, ShardDecision,
                                 balanced_ranges, plan_shards,
                                 shard_table_name)
from repro.serving.engine import RelationalEngine
from repro.serving.shards import ShardWorkerPool, slice_table

# wide enough that every matmul site passes the benefit > combine-cost
# pricing gate (8×8 weights are refused: the combine pass costs more
# than the split saves)
SPEC = LlamaSpec(vocab=64, d_model=32, n_layers=1, n_heads=4, n_kv=2,
                 d_ff=64, rope_theta=10000.0)
CS = 4


def _linear_pipe(cs=4, d=32):
    """Embedding→linear with a ``d×d`` weight (wide enough to shard)."""
    g = Graph(name="lin")
    g.inputs = ["ids"]
    g.annotate("ids", (("t", 4),))
    g.annotate("vocab", (("tok", 16), ("d", d)))
    g.initializers["vocab"] = None
    g.initializers["W"] = None
    g.annotate("W", (("j", d), ("d", d)))
    x = g.add("embedding", ["vocab", "ids"])
    g.add("linear", [x, "W"], out_features=d, output="y")
    g.outputs = ["y"]
    infer_shapes(g)
    return op_map(g, chunk_size=cs)


def _decode_pipe(**post_kw):
    g = build_decode_graph(SPEC, cache_len=8)
    infer_shapes(g)
    preoptimize(g)
    pipe = op_map(g, chunk_size=CS)
    postoptimize(pipe, **post_kw)
    return pipe


class TestBalancedRanges:
    def test_even_split(self):
        assert balanced_ranges(8, 2) == ((0, 4), (4, 8))
        assert balanced_ranges(8, 4) == ((0, 2), (2, 4), (4, 6), (6, 8))

    def test_uneven_split_stays_contiguous_and_covering(self):
        for size, n in ((7, 3), (10, 4), (5, 2)):
            rs = balanced_ranges(size, n)
            assert rs[0][0] == 0 and rs[-1][1] == size
            assert all(a[1] == b[0] for a, b in zip(rs, rs[1:]))
            widths = [hi - lo for lo, hi in rs]
            assert max(widths) - min(widths) <= 1

    def test_n_clamped_to_size(self):
        assert balanced_ranges(2, 8) == ((0, 1), (1, 2))
        assert balanced_ranges(4, 1) == ((0, 4),)

    def test_shard_table_name(self):
        assert shard_table_name("W__col", 3) == "W__col::shard3"


class TestShardPlanning:
    def test_col_layout_decode_sites(self):
        pipe = _decode_pipe(layout_mode="col", cache_mode="auto")
        plan = plan_shards(pipe, 2)
        assert pipe.shard_plan is plan and plan.decisions
        kinds = {d.kind for d in plan.decisions}
        assert kinds <= {"row", "col", "colh"}
        assert "colh" in kinds  # Q/K/V head-blocked projections
        for d in plan.decisions:
            assert d.axis_size >= 2
            assert d.ranges == balanced_ranges(d.axis_size, 2)
            assert d.combine in (COMBINE_SUM, COMBINE_CONCAT)
            assert len(d.shard_roots) == d.n_shards == 2
            assert plan.table_ranges[d.table] == d.ranges
        # attention's cache-table scans are never sharded
        cache = set(pipe.cache_tables)
        assert not any(d.table in cache for d in plan.decisions)
        # by_step preserves planner post-order per step
        for step, decs in plan.by_step.items():
            assert [d for d in plan.decisions
                    if d.step_name == step] == decs

    def test_n1_keeps_pipeline_unsharded(self):
        pipe = _decode_pipe(layout_mode="col")
        plan = plan_shards(pipe, 1)
        assert pipe.shard_plan is None
        assert plan.n_shards == 1 and not plan.decisions

    def test_pricing_refuses_tiny_sites(self):
        # an 8×8 row-chunk weight: the SUM combine stacks N full copies
        # of the output groups, which costs more than the split saves on
        # a site this small — no decision is recorded
        pipe = _linear_pipe(d=8)
        assert plan_shards(pipe, 2).decisions == []
        assert pipe.shard_plan is None

    def test_admitted_site_prices_benefit_over_combine(self):
        pipe = _linear_pipe(d=32)
        plan_layouts(pipe, mode="col")
        (dec,) = plan_shards(pipe, 2).decisions
        assert dec.table == "W__col" and dec.kind == "col"
        assert dec.benefit > dec.combine_cost > 0


GOLDEN_SHARD_SLICE = """\
CREATE OR REPLACE TABLE W__col__shard0 AS
SELECT * FROM W__col WHERE c >= 0 AND c < 4;"""

GOLDEN_SHARD_VIEW = """\
CREATE OR REPLACE VIEW y__s0__shard0 AS
WITH t4 AS (SELECT S.t, S.c, E.e, S.v[E.e + 1] AS x FROM embedding_1 AS S, (SELECT UNNEST(range(4)) AS e) AS E),
  t3 AS (SELECT t AS t, ((c * 4) + e) AS d, x AS xs FROM t4),
  t2 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t3 AS L JOIN W__col__shard0 AS R ON R.d = L.d)
SELECT t, c, sumForEach(LIST(list_transform(chunk, x -> x * (xs)))) AS v FROM t2 GROUP BY t, c;"""

GOLDEN_CONCAT_COMBINE = """\
CREATE OR REPLACE VIEW y__s0__combine AS
-- key-disjoint shard combine (contiguous c ranges)
SELECT * FROM y__s0__shard0
UNION ALL
SELECT * FROM y__s0__shard1;"""

GOLDEN_SUM_COMBINE = """\
CREATE OR REPLACE VIEW y__s0__combine AS
-- row-parallel shard combine (UNION ALL + SUM over partial sums)
SELECT t, j, SUM(s) AS s FROM (
SELECT * FROM y__s0__shard0
UNION ALL
SELECT * FROM y__s0__shard1
) AS S
GROUP BY t, j;"""


class TestShardSQL:
    def test_n1_sql_bit_identical_to_unsharded(self):
        def sql(n):
            pipe = _decode_pipe(layout_mode="col", cache_mode="auto")
            if n is not None:
                plan_shards(pipe, n)
            return generate_sql(pipe, dialect="duckdb",
                                include_conversion=True)
        assert sql(None) == sql(1)

    def test_golden_col_shard_script(self):
        pipe = _linear_pipe(d=32)
        plan_layouts(pipe, mode="col")
        plan_shards(pipe, 2)
        sql = generate_sql(pipe, dialect="duckdb", include_conversion=True)
        assert ("-- SHARD data conversion (contiguous key-range slices "
                "of the stored weight tables)") in sql
        assert GOLDEN_SHARD_SLICE in sql
        assert GOLDEN_SHARD_VIEW in sql
        assert GOLDEN_CONCAT_COMBINE in sql
        # the step IS the matmul site: its view selects from the combine
        assert "CREATE OR REPLACE VIEW y AS\n" \
               "SELECT * FROM y__s0__combine;" in sql

    def test_golden_row_shard_combine(self):
        # without the col rewrite the join binds the reduction chunk key:
        # a row-parallel site whose combine is UNION ALL + SUM
        pipe = _linear_pipe(d=32)
        (dec,) = plan_shards(pipe, 2).decisions
        assert dec.kind == "row" and dec.combine == COMBINE_SUM
        assert dec.table == "W" and dec.left_key == "c"
        sql = generate_sql(pipe, dialect="duckdb", include_conversion=True)
        assert GOLDEN_SUM_COMBINE in sql
        # the step's unsharded tail (re-chunk) reads the combine by name
        assert "FROM y__s0__combine" in sql

    def test_shard_statement_provenance(self):
        from repro.core.sqlgen import generate_sql_with_provenance
        pipe = _linear_pipe(d=32)
        plan_layouts(pipe, mode="col")
        plan_shards(pipe, 2)
        pairs = generate_sql_with_provenance(pipe, dialect="duckdb",
                                             include_conversion=True)
        slices = [p for _, p in pairs if p.kind == "conversion"
                  and p.target and "::shard" in p.target]
        assert [p.shard for p in slices] == [0, 1]
        partials = [p for _, p in pairs if p.kind == "bind"
                    and p.shard is not None]
        assert [p.shard for p in partials] == [0, 1]
        combines = [p for _, p in pairs if "shard_combine" in p.ops]
        assert len(combines) == 1 and combines[0].shard is None
        assert combines[0].tables == ("W__col::shard0", "W__col::shard1")


class TestWorkerPoolUnits:
    def test_slice_table_broadcasts_lazy_columns(self):
        full = np.arange(24, dtype=np.float32).reshape(6, 4)
        lazy = np.full((1,), 2.5, np.float32)  # broadcast over key "c"
        t = DenseTable(keys=(("c", 6),),
                       cols={"v": full, "s": lazy},
                       col_types={"v": ra.VEC(4), "s": ra.SCALAR})
        s = slice_table(t, "c", 2, 5)
        assert s.keys == (("c", 3),)
        np.testing.assert_array_equal(np.asarray(s.cols["v"]), full[2:5])
        # the lazily-broadcast scalar column was expanded then sliced
        np.testing.assert_array_equal(np.asarray(s.cols["s"]),
                                      np.full(3, 2.5, np.float32))

    def _partials(self, combine, axis="c"):
        dec = ShardDecision(step_name="s", table="W", axis=axis,
                            axis_size=4, kind="row", combine=combine,
                            logical_axis="inner", ranges=((0, 2), (2, 4)))
        mk = lambda a: DenseTable(keys=(("c", a.shape[0]),),
                                  cols={"v": a},
                                  col_types={"v": ra.VEC(2)})
        a = np.arange(8, dtype=np.float32).reshape(4, 2)
        b = 10 * np.ones((4, 2), np.float32)
        return dec, mk(a), mk(b), a, b

    def test_combine_sum_adds_partials(self):
        dec, ta, tb, a, b = self._partials(COMBINE_SUM)
        out = ShardWorkerPool._combine(dec, [ta, tb])
        assert out.keys == ta.keys
        np.testing.assert_allclose(np.asarray(out.cols["v"]), a + b)

    def test_combine_concat_stacks_along_shard_key(self):
        dec, ta, tb, a, b = self._partials(COMBINE_CONCAT)
        out = ShardWorkerPool._combine(dec, [ta, tb])
        assert out.keys == (("c", 8),)
        np.testing.assert_allclose(np.asarray(out.cols["v"]),
                                   np.concatenate([a, b]))

    def test_pool_rejects_single_worker(self):
        with pytest.raises(ValueError):
            ShardWorkerPool(1)


@pytest.fixture(scope="module")
def params():
    return init_llama_params(SPEC, seed=0)


def _tokens(eng, prompt=(3, 17, 42), steps=3):
    sess = eng.start_session(list(prompt))
    toks = [sess["tok"]]
    for _ in range(steps):
        toks.append(eng.session_step(sess))
    return toks


class TestShardedEngine:
    def test_in_memory_matches_unsharded(self, params):
        ref = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8)
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               shards=2)
        assert eng.decode_pipe.shard_plan is not None
        assert _tokens(eng) == _tokens(ref)
        assert eng.shard_pool.stats.sites > 0
        assert eng.shard_pool.stats.fanout_s >= \
            eng.shard_pool.stats.critical_s > 0
        eng.shard_pool.shutdown()

    def test_paged_matches_unsharded(self, params):
        ref = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8)
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               residency="paged", budget_bytes=1 << 22,
                               pager_policy="clock", shards=2)
        assert _tokens(eng) == _tokens(ref)
        # each worker pages its slices under its own budget share
        assert all(w.pager is not None and w.pager.stats.misses > 0
                   for w in eng.shard_pool.workers)
        eng.shard_pool.shutdown()

    def test_paged_quantised_matches_unsharded_quantised(self, params):
        ref = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               precision="int8")
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               residency="paged", budget_bytes=1 << 22,
                               precision="int8", shards=2)
        assert eng.table_precision_choices  # the planner did quantise
        assert _tokens(eng) == _tokens(ref)
        eng.shard_pool.shutdown()

    def test_shards_validation(self, params):
        with pytest.raises(ValueError):
            RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                             shards=0.5)
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               shards=1)
        assert eng.shard_pool is None and eng.decode_pipe.shard_plan is None


class TestShardObservability:
    def test_merged_metrics_and_trace(self, params):
        from repro.obs import MetricsRegistry, TraceRecorder
        reg = MetricsRegistry()
        tracer = TraceRecorder()
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8,
                               shards=2, metrics=reg, tracer=tracer)
        _tokens(eng, steps=2)
        eng.merge_shard_metrics()
        dump = reg.to_dict()
        runs = {e["labels"]["shard"]: e["value"]
                for e in dump["shard_worker_runs_total"]}
        assert set(runs) == {"0", "1"}
        assert runs["0"] == runs["1"] > 0
        busy = [e for e in dump["shard_worker_busy_seconds"]
                if e["labels"].get("shard") == "0"]
        assert busy and busy[0]["count"] == runs["0"]
        merged = eng.merged_shard_trace()
        pids = {e["pid"] for e in merged["traceEvents"]}
        assert pids == {1, 2, 3}  # coordinator + 2 worker tracks
        shard_spans = [e for e in merged["traceEvents"]
                       if e["cat"] == "shard"]
        assert shard_spans
        assert {e["args"]["track"] for e in shard_spans} == \
            {"shard0", "shard1"}
        eng.shard_pool.shutdown()

    def test_unsharded_engine_has_no_shard_surface(self, params):
        eng = RelationalEngine(SPEC, params, chunk_size=CS, max_len=8)
        eng.merge_shard_metrics()  # no-op, must not raise
        assert eng.merged_shard_trace() is None
