"""Request-scoped trace context, flight recorder and drift watchdog
(ISSUE 10 tentpole).

Covers the three new observability pieces end to end but without
sockets (the HTTP surface rides in ``test_server.py``): contextvars
propagation into spans/events, the bounded tick ring with pinning and
windowed reads under concurrent writers, and the watchdog's
observe → refit → re-plan loop — including the acceptance property
that a re-plan landing in the middle of a live decode session leaves
the generated tokens exactly as an unperturbed run produces them.
"""

import threading
import warnings

import pytest

from repro.core.llama_graph import LlamaSpec, init_llama_params
from repro.obs.context import (TraceContext, activate, current_context,
                               new_trace_id)
from repro.obs.flight import FlightRecorder
from repro.obs.log import log_event, set_flight_recorder
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanEvent, TraceRecorder
from repro.serving.watchdog import DriftWatchdog

SPEC = LlamaSpec(vocab=16, d_model=8, n_layers=1, n_heads=2, n_kv=1,
                 d_ff=16, rope_theta=10000.0)


def _engine(**kw):
    from repro.serving.engine import RelationalEngine
    return RelationalEngine(SPEC, init_llama_params(SPEC, seed=0),
                            chunk_size=4, max_len=16, **kw)


def _step_span(name, ts, dur, **args):
    return SpanEvent(name=name, cat="step", ts_us=ts, dur_us=dur,
                     depth=0, args=args)


class TestTraceContext:
    def test_trace_ids_are_short_hex_and_unique(self):
        ids = {new_trace_id() for _ in range(64)}
        assert len(ids) == 64
        assert all(len(t) == 16 and int(t, 16) >= 0 for t in ids)

    def test_activate_scopes_and_nests(self):
        assert current_context() is None
        outer = TraceContext.for_request(1, "aa", phase="prefill")
        inner = TraceContext(request_ids=(1, 2), trace_ids=("aa", "bb"),
                             phase="decode", tick=7)
        with activate(outer):
            assert current_context() is outer
            with activate(inner):
                assert current_context().phase == "decode"
            # None deactivates: work serving no particular request
            with activate(None):
                assert current_context() is None
            assert current_context() is outer
        assert current_context() is None

    def test_span_auto_attaches_context(self):
        rec = TraceRecorder()
        ctx = TraceContext(request_ids=(3, 4), trace_ids=("x1", "x2"),
                           phase="decode", tick=9)
        with activate(ctx):
            with rec.span("attn", cat="step", phase="explicit"):
                pass
            rec.add_span("fetch", cat="pager", ts_us=0.0, dur_us=1.0)
        with rec.span("outside", cat="step"):
            pass
        by_name = {e.name: e.args for e in rec.events}
        assert by_name["attn"]["rids"] == [3, 4]
        assert by_name["attn"]["trace_ids"] == ["x1", "x2"]
        # explicit kwargs win over the ambient context on collision
        assert by_name["attn"]["phase"] == "explicit"
        assert by_name["fetch"]["tick"] == 9
        assert "rids" not in by_name["outside"]

    def test_context_does_not_cross_threads(self):
        # contextvars are thread-local: worker threads see no context
        # unless they re-activate a captured one (the shard pool does)
        seen = {}
        ctx = TraceContext.for_request(5, "cc")

        def worker():
            seen["bare"] = current_context()
            with activate(ctx):
                seen["activated"] = current_context()

        with activate(ctx):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert seen["bare"] is None
        assert seen["activated"] is ctx


class TestLogEventFlightForwarding:
    def test_event_carries_context_and_lands_in_flight(self):
        flight = FlightRecorder()
        set_flight_recorder(flight)
        try:
            ctx = TraceContext.for_request(8, "ee", phase="decode", tick=3)
            with activate(ctx):
                log_event("unit_test_event", detail="x")
            log_event("unit_test_event_bare")
        finally:
            set_flight_recorder(None)
        evs = flight.events()
        assert [e.event for e in evs] == ["unit_test_event",
                                         "unit_test_event_bare"]
        assert evs[0].fields["rids"] == [8]
        assert evs[0].fields["trace_ids"] == ["ee"]
        assert evs[0].fields["detail"] == "x"
        assert "rids" not in evs[1].fields
        # both on the recorder's monotonic timeline, in order
        assert evs[0].ts_us <= evs[1].ts_us


class TestFlightRecorder:
    def test_ring_bounded_with_eviction(self):
        fl = FlightRecorder(capacity=4)
        for i in range(10):
            fl.record_tick("decode", tick=i, request_ids=(i,),
                           trace_ids=(f"t{i}",))
        assert len(fl.ticks()) == 4
        assert fl.dropped_ticks == 6
        assert [t.tick for t in fl.ticks()] == [6, 7, 8, 9]
        # evicted, unpinned requests leave the index entirely
        assert fl.request_ticks("t0") == []
        assert fl.request_ticks("0") == []
        assert fl.request_ticks("t9")[0].tick == 9

    def test_index_accepts_rid_and_trace_id(self):
        fl = FlightRecorder()
        fl.record_admission(7, "abc123", wall_us=50.0)
        fl.record_tick("prefill", tick=1, request_ids=(7,),
                       trace_ids=("abc123",))
        assert [t.kind for t in fl.request_ticks("abc123")] == \
            ["admission", "prefill"]
        assert fl.request_ticks("7") == fl.request_ticks("abc123")

    def test_pinned_exemplars_survive_eviction(self):
        fl = FlightRecorder(capacity=2, max_pinned=2)
        fl.record_tick("decode", tick=0, request_ids=(1,),
                       trace_ids=("slow",))
        fl.pin("slow", reason="slo")
        # future ticks for a pinned trace are pinned as they arrive
        fl.record_tick("decode", tick=1, request_ids=(1, 2),
                       trace_ids=("slow", "fast"))
        for i in range(2, 7):
            fl.record_tick("decode", tick=i, trace_ids=(f"x{i}",))
        # both "slow" ticks fell out of the ring yet stay reachable
        assert len(fl.ticks()) == 2
        assert [t.tick for t in fl.request_ticks("slow")] == [0, 1]
        assert all(t.pinned for t in fl.request_ticks("slow"))
        # ... and the LRU pin bound evicts the oldest pin
        fl.pin("p1")
        fl.pin("p2")
        assert "slow" not in fl.to_dict()["pinned"]

    def test_step_times_us_windowing(self):
        fl = FlightRecorder()
        fl.record_tick("decode", spans=(_step_span("a", 0, 100.0),
                                        _step_span("b", 100, 50.0)))
        fl.record_tick("prefill", spans=(_step_span("a", 200, 999.0),))
        fl.record_tick("decode", spans=(_step_span("a", 300, 10.0),))
        obs, last = fl.step_times_us(kind="decode", cat="step")
        assert obs == {"a": 110.0, "b": 50.0}   # prefill tick excluded
        # the returned watermark makes the next read incremental
        fl.record_tick("decode", spans=(_step_span("b", 400, 7.0),))
        obs2, last2 = fl.step_times_us(kind="decode", cat="step",
                                       after_seq=last)
        assert obs2 == {"b": 7.0}
        assert last2 > last
        obs3, _ = fl.step_times_us(kind="decode", cat="step",
                                   after_seq=last2)
        assert obs3 == {}

    def test_request_trace_reconstructs_end_to_end(self):
        fl = FlightRecorder()
        fl.record_admission(3, "tid3", wall_us=40.0, tick=0)
        fl.record_tick(
            "prefill", tick=1, request_ids=(3,), trace_ids=("tid3",),
            wall_us=100.0,
            spans=(_step_span("embed", 50, 60.0, trace_ids=["tid3"]),
                   _step_span("attn", 110, 40.0, trace_ids=["tid3"])))
        # a batched decode tick shared with another request: spans tagged
        # for the other request only must not leak into this trace
        fl.record_tick(
            "decode", tick=2, request_ids=(3, 4),
            trace_ids=("tid3", "tid4"), wall_us=80.0,
            spans=(_step_span("attn", 200, 80.0,
                              trace_ids=["tid3", "tid4"]),
                   _step_span("other_only", 200, 5.0,
                              trace_ids=["tid4"])))
        trace = fl.request_trace("tid3")
        assert trace["request_id"] == 3 and trace["trace_id"] == "tid3"
        assert [t["kind"] for t in trace["ticks"]] == \
            ["admission", "prefill", "decode"]
        assert trace["wall_us"] == pytest.approx(220.0)
        assert 0.0 < trace["coverage"] <= 1.0
        names = [e["name"] for e in trace["traceEvents"]]
        assert "other_only" not in names
        assert "embed" in names and "attn" in names
        # the rid is an equally good key for the same reconstruction
        assert fl.request_trace("3")["trace_id"] == "tid3"
        assert fl.request_trace("deadbeef") is None

    def test_coverage_counts_depth0_only_and_clips(self):
        fl = FlightRecorder()
        t = fl.record_tick(
            "decode", wall_us=100.0,
            spans=(_step_span("a", 0, 80.0),
                   SpanEvent(name="sub", cat="op", ts_us=0, dur_us=70.0,
                             depth=1),       # nested: already counted
                   _step_span("b", 80, 40.0)))  # overshoot: clip at 1.0
        assert t.named_us() == pytest.approx(120.0)
        assert t.coverage() == 1.0
        t2 = fl.record_tick("decode", wall_us=100.0,
                            spans=(_step_span("a", 0, 25.0),))
        assert t2.coverage() == pytest.approx(0.25)

    def test_to_dict_and_chrome_are_serialisable(self):
        import json
        fl = FlightRecorder()
        fl.record_admission(1, "t1", wall_us=10.0)
        fl.record_tick("decode", spans=(_step_span("a", 0, 5.0),),
                       wall_us=5.0, request_ids=(1,), trace_ids=("t1",))
        fl.record_event("evt", {"k": "v"})
        d = fl.to_dict()
        assert d["retained_ticks"] == 2 and d["indexed_requests"] >= 1
        assert d["events"][0]["event"] == "evt"
        json.dumps(d)
        chrome = fl.to_chrome()
        assert {e["ph"] for e in chrome["traceEvents"]} == {"X", "i"}
        json.dumps(chrome)


class TestFlightConcurrency:
    def test_one_writer_many_readers_stay_consistent(self):
        """The serving topology: the scheduler thread writes ticks while
        HTTP handler threads snapshot through every read path.  Nothing
        may raise, and the final accounting must be exact."""
        fl = FlightRecorder(capacity=32, event_capacity=64)
        n_ticks = 600
        stop = threading.Event()
        errors = []

        def writer():
            try:
                for i in range(n_ticks):
                    fl.record_tick(
                        "decode" if i % 3 else "prefill", tick=i,
                        request_ids=(i % 8,), trace_ids=(f"t{i % 8}",),
                        wall_us=10.0,
                        spans=(_step_span("s", i * 10.0, 10.0),))
                    if i % 7 == 0:
                        fl.record_event("beat", {"i": i})
                    if i == n_ticks // 2:
                        fl.pin(f"t{i % 8}")
            except Exception as e:           # pragma: no cover
                errors.append(e)
            finally:
                stop.set()

        def reader():
            try:
                while not stop.is_set():
                    fl.to_dict()
                    fl.step_times_us(kind="decode", cat="step")
                    fl.request_trace(f"t{len(fl.ticks()) % 8}")
                    fl.to_chrome()
            except Exception as e:           # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=writer)] + \
            [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert len(fl.ticks()) == 32
        assert fl.dropped_ticks == n_ticks - 32
        # seq numbers stayed strictly monotonic through the contention
        seqs = [t.seq for t in fl.ticks()]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


class TestDriftWatchdog:
    def test_cadence_and_empty_window_skip(self):
        eng = _engine()
        fl = FlightRecorder()
        wd = DriftWatchdog(eng, fl, every=3)
        assert [wd.on_tick() for _ in range(6)] == [False] * 6
        assert wd.ticks == 6 and wd.checks == 0   # no decode ticks yet

    def test_unjoinable_window_advances_watermark(self):
        eng = _engine()
        fl = FlightRecorder()
        wd = DriftWatchdog(eng, fl, every=1)
        fl.record_tick("decode", spans=(_step_span("not_a_step", 0, 5.0),))
        assert wd.on_tick() is False
        assert wd.checks == 0
        # the window was consumed even though it didn't join
        assert fl.step_times_us(kind="decode", cat="step",
                                after_seq=wd._after_seq)[0] == {}

    def test_errors_never_escape(self):
        class Boom:
            def step_times_us(self, **kw):
                raise RuntimeError("boom")
        wd = DriftWatchdog(object(), Boom(), every=1)
        assert wd.on_tick() is False
        assert wd.errors == 1

    def test_every_must_be_positive(self):
        with pytest.raises(ValueError):
            DriftWatchdog(object(), FlightRecorder(), every=0)

    def test_on_model_window_does_not_replan(self):
        eng = _engine()
        fl = FlightRecorder()
        reg = MetricsRegistry()
        wd = DriftWatchdog(eng, fl, every=1, threshold=0.5, metrics=reg)
        feats = wd._features()
        assert len(feats) >= wd.min_points
        # observed exactly on the cost model's shape: near-zero drift
        spans = tuple(_step_span(s, i * 100.0, 2.0 * (r + g) + 5.0)
                      for i, (s, (r, g)) in enumerate(sorted(feats.items())))
        fl.record_tick("decode", spans=spans)
        assert wd.on_tick() is False
        assert wd.checks == 1 and wd.replans == 0 and eng.replans == 0
        assert wd.last_report is not None
        assert wd.last_report.rms_rel_drift < 0.5
        assert reg.gauge("drift_watchdog_rms_rel_drift").value == \
            wd.last_report.rms_rel_drift

    def test_replan_mid_session_is_token_exact(self):
        """The acceptance scenario: perturbed step timings push drift past
        the threshold, the watchdog refits and re-plans while a decode
        session is live, and the session's remaining tokens still match
        the unperturbed sequential reference exactly."""
        eng = _engine(metrics=MetricsRegistry())
        prompt = [3, 5, 7]
        ref = eng.generate(prompt, max_new_tokens=6).tokens

        fl = FlightRecorder()
        wd = DriftWatchdog(eng, fl, every=2, threshold=0.25,
                           metrics=eng.metrics)
        feats = wd._features()
        assert len(feats) >= wd.min_points
        # perturbation: alternate steps run 8x over the model's shape —
        # high RMS relative drift no uniform host slowdown could explain
        ts, spans = 0.0, []
        for i, (s, (r, g)) in enumerate(sorted(feats.items())):
            us = (r + g) * (8.0 if i % 2 else 1.0) + 5.0
            spans.append(_step_span(s, ts, us))
            ts += us
        fl.record_tick("decode", spans=tuple(spans), wall_us=ts, tick=1)

        sess = eng.start_session(prompt)
        toks = [sess["tok"], eng.session_step(sess)]
        assert wd.on_tick() is False          # tick 1 of 2: off-cadence
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # refit may warn on noise
            fired = wd.on_tick()
        assert fired is True
        assert wd.replans == 1 and eng.replans == 1
        assert wd.last_report.rms_rel_drift > wd.threshold
        assert wd.last_fit is not None and wd.last_fit.n_points >= 4
        assert eng.metrics.counter("engine_replans_total").value == 1
        assert eng.metrics.counter(
            "drift_watchdog_replans_total").value == 1
        # the live session decodes on across the plan-cache swap ...
        for _ in range(4):
            toks.append(eng.session_step(sess))
        # ... token-exact against the unperturbed reference
        assert toks == ref

    def test_to_dict_shape(self):
        eng = _engine()
        wd = DriftWatchdog(eng, FlightRecorder(), every=5, threshold=0.4,
                           batch=2)
        d = wd.to_dict()
        assert d["every"] == 5 and d["threshold"] == 0.4 and d["batch"] == 2
        assert d["last_report"] is None and d["last_fit"] is None
        assert d["engine_replans"] == 0
