"""Distribution tests — run in a subprocess with 8 forced host devices so
the main pytest process keeps its single-device view (the dry-run contract).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.distributed.collectives import ef_int8_allreduce, hierarchical_psum
from repro.distributed.pipeline import pipeline_apply
from repro.distributed.sharding import sharding_rules, single_pod_rules, \
    multi_pod_rules
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as tf
from repro.training.optimizer import AdamW
from repro.training.train_loop import make_train_step, param_pspecs
from repro.training import checkpoint as ckpt

results = {}

# ---- 1. pjit train step: 2x2 mesh == single device -------------------------
cfg = get_config("llama3-8b", tiny=True)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
state = opt.init(params)
data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=8)
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

step = make_train_step(cfg, opt)
p1, s1, m1 = jax.jit(step)(params, state, batch)
loss_single = float(m1["loss"])

mesh = make_test_mesh(2, 2)
with mesh, sharding_rules(mesh, single_pod_rules(fsdp=True)):
    specs = param_pspecs(params, mesh)
    shardings = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
    params_sh = jax.tree_util.tree_map(jax.device_put, params, shardings)
    state_sh = opt.init(params_sh)
    batch_sh = {k: jax.device_put(v, NamedSharding(mesh, P("data")))
                for k, v in batch.items()}
    p2, s2, m2 = jax.jit(step)(params_sh, state_sh, batch_sh)
    loss_mesh = float(m2["loss"])
results["pjit_single_vs_mesh"] = abs(loss_single - loss_mesh)

# ---- 2. multi-pod mesh (2x2x2) ---------------------------------------------
mesh3 = make_test_mesh(2, 2, pods=2)
with mesh3, sharding_rules(mesh3, multi_pod_rules(fsdp=True)):
    specs = param_pspecs(params, mesh3)
    shardings = jax.tree_util.tree_map(lambda s: NamedSharding(mesh3, s), specs)
    params_sh = jax.tree_util.tree_map(jax.device_put, params, shardings)
    state_sh = opt.init(params_sh)
    batch_sh = {k: jax.device_put(v, NamedSharding(mesh3, P(("pod", "data"))))
                for k, v in batch.items()}
    p3, s3, m3 = jax.jit(step)(params_sh, state_sh, batch_sh)
results["pjit_multipod_loss_delta"] = abs(loss_single - float(m3["loss"]))

# ---- 3. elastic reshard: save on 2x2, restore on 2x2x2 ----------------------
ckpt_dir = "/tmp/repro_elastic_test"
import shutil; shutil.rmtree(ckpt_dir, ignore_errors=True)
ckpt.save(ckpt_dir, 1, p2)
abstract = jax.tree_util.tree_map(
    lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params)
with mesh3, sharding_rules(mesh3, multi_pod_rules(fsdp=True)):
    specs3 = param_pspecs(abstract, mesh3)
    sh3 = jax.tree_util.tree_map(lambda s: NamedSharding(mesh3, s), specs3)
    restored, _ = ckpt.restore(ckpt_dir, 1, abstract, sh3)
d = jax.tree_util.tree_map(
    lambda a, b: float(np.max(np.abs(
        np.asarray(jax.device_get(a), np.float32)
        - np.asarray(jax.device_get(b), np.float32)))), p2, restored)
results["elastic_reshard_delta"] = max(jax.tree_util.tree_leaves(d))

# ---- 4. pipeline parallelism: 4 stages == dense ------------------------------
pmesh = jax.make_mesh((4,), ("pod",))
L, D = 8, 16
keys = jax.random.split(jax.random.PRNGKey(1), L)
blocks = {"w": jax.vmap(lambda k: jax.random.normal(k, (D, D)) * 0.1)(keys)}
x = jax.random.normal(jax.random.PRNGKey(2), (8, D))

def block_fn(p, x):
    return jnp.tanh(x @ p["w"])

dense = x
for i in range(L):
    dense = block_fn({"w": blocks["w"][i]}, dense)
piped = pipeline_apply(block_fn, blocks, x, pmesh, stage_axis="pod",
                       n_micro=4)
results["pipeline_vs_dense"] = float(jnp.max(jnp.abs(dense - piped)))

# ---- 5. collectives: hierarchical psum + int8 EF all-reduce ------------------
mesh3b = make_test_mesh(2, 2, pods=2)
xs = jax.random.normal(jax.random.PRNGKey(3), (8, 16))

def hier(x):
    return hierarchical_psum(x, "pod", "data")

def plain(x):
    return jax.lax.psum(x, ("pod", "data"))

sm = lambda f: shard_map(f, mesh=mesh3b, in_specs=P(None, "model"),
                         out_specs=P(None, "model"), check_rep=False)
a = sm(hier)(xs)
b = sm(plain)(xs)
results["hier_psum_delta"] = float(jnp.max(jnp.abs(a - b)))

g = jax.random.normal(jax.random.PRNGKey(4), (4, 8)) * 0.1
err0 = jnp.zeros((4, 8))

def efar(g, e):
    return ef_int8_allreduce(g, e, ("data",))

mean_g, new_err = shard_map(
    efar, mesh=mesh3b, in_specs=(P(None, None), P(None, None)),
    out_specs=(P(None, None), P(None, None)), check_rep=False)(g, err0)
# all shards hold the same g ⇒ mean == g up to int8 quantisation error
results["ef_int8_error"] = float(jnp.max(jnp.abs(mean_g - g)))
results["ef_feedback_nonzero"] = float(jnp.max(jnp.abs(new_err)))

print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS ")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[-1][len("RESULTS "):])


def test_pjit_mesh_matches_single_device(dist_results):
    assert dist_results["pjit_single_vs_mesh"] < 5e-3


def test_multipod_mesh_runs(dist_results):
    assert dist_results["pjit_multipod_loss_delta"] < 5e-3


def test_elastic_reshard_exact(dist_results):
    assert dist_results["elastic_reshard_delta"] == 0.0


def test_pipeline_parallel_matches_dense(dist_results):
    assert dist_results["pipeline_vs_dense"] < 1e-5


def test_hierarchical_psum_matches_plain(dist_results):
    assert dist_results["hier_psum_delta"] < 1e-5


def test_int8_error_feedback_allreduce(dist_results):
    assert dist_results["ef_int8_error"] < 2e-3     # quantisation bounded
    assert dist_results["ef_feedback_nonzero"] > 0  # residual carried
