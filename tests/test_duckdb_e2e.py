"""DuckDB end-to-end integration: execute the emitted DDL + ROW2COL
conversion SQL + pipeline views against a *real* DuckDB and compare with
the JAX columnar executor.

The golden-SQL snapshots in test_planner.py never run; this module closes
the loop (ROADMAP "DuckDB end-to-end run").  Gated on ``duckdb`` being
importable — the paper's evaluation engine is an optional dependency.

Glue applied before execution (documented test-only shims, not generator
changes):
  * ``FLOAT[n]`` fixed-size array columns become ``FLOAT[]`` lists — the
    Appendix-B UDF macros are written against DuckDB's list functions.
  * the ``:cache_position`` placeholder is substituted with its literal
    value (DuckDB's python API uses ``$name``-style parameters).
"""

import os
import re

import numpy as np
import pytest

duckdb = pytest.importorskip("duckdb")

from repro.core.graph import Graph, infer_shapes
from repro.core.llama_graph import (LlamaSpec, build_decode_graph,
                                    convert_weights, empty_cache_tables,
                                    init_llama_params, rope_freq_table,
                                    token_table)
from repro.core.opmap import op_map
from repro.core.passes import postoptimize, preoptimize
from repro.core.pipeline import run_pipeline
from repro.core.sqlgen import generate_sql

SPEC = LlamaSpec(vocab=16, d_model=8, n_layers=1, n_heads=2, n_kv=1,
                 d_ff=16, rope_theta=10000.0)
CS = 4


def _listify(sql: str) -> str:
    return re.sub(r"(FLOAT|TINYINT|UTINYINT)\[\d+\]", r"\1[]", sql)


def _split_script(sql: str):
    """(ddl, conversion, rest) sections of a generated script."""
    i_conv = sql.find("-- ROW2COL data conversion")
    i_views = sql.find("CREATE OR REPLACE VIEW")
    if i_views < 0:
        i_views = len(sql)
    if i_conv < 0:
        return sql[:i_views], "", sql[i_views:]
    return sql[:i_conv], sql[i_conv:i_views], sql[i_views:]


def _run_statements(con, script: str) -> None:
    for stmt in script.split(";"):
        body = "\n".join(l for l in stmt.splitlines()
                         if not l.strip().startswith("--")).strip()
        if body:
            con.execute(body + ";")


def _insert_table(con, name: str, key_sizes, payload) -> None:
    """Insert a dense [*, ...] array as relational rows (key order = axis
    order = DDL column order for row-layout tables)."""
    arr = np.asarray(payload, np.float32)
    rows = []
    for idx in np.ndindex(*key_sizes):
        v = arr[idx]
        rows.append(tuple(int(i) for i in idx)
                    + ((v.tolist(),) if v.ndim else (float(v),)))
    ph = ", ".join("?" * len(rows[0]))
    con.executemany(f"INSERT INTO {name} VALUES ({ph})", rows)


def _insert_dense_tables(con, env, names) -> None:
    for name in names:
        t = env[name]
        if len(t.cols) == 1:
            (cname, arr), = t.cols.items()
            _insert_table(con, name, t.key_sizes, np.asarray(arr))
        else:  # multi-column input (freq table): zip columns row-wise
            arrs = {c: np.asarray(a) for c, a in t.cols.items()}
            rows = []
            for idx in np.ndindex(*t.key_sizes):
                row = tuple(int(i) for i in idx)
                for c, a in arrs.items():
                    v = a[idx]
                    row += (v.tolist(),) if v.ndim else (float(v),)
                rows.append(row)
            ph = ", ".join("?" * len(rows[0]))
            con.executemany(f"INSERT INTO {name} VALUES ({ph})", rows)


class TestLinearEndToEnd:
    """Embedding → linear with the ROW2COL conversion, end to end."""

    def _pipe(self):
        g = Graph(name="lin")
        g.inputs = ["ids"]
        g.annotate("ids", (("t", 4),))
        g.annotate("vocab", (("tok", 16), ("d", 8)))
        g.initializers["vocab"] = None
        g.initializers["W"] = None
        g.annotate("W", (("j", 8), ("d", 8)))
        x = g.add("embedding", ["vocab", "ids"])
        g.add("linear", [x, "W"], out_features=8, output="y")
        g.outputs = ["y"]
        infer_shapes(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe, layout_mode="col")
        return pipe

    def test_conversion_and_query_match_numpy(self):
        pipe = self._pipe()
        rng = np.random.default_rng(0)
        w = {"vocab": rng.standard_normal((16, 8)).astype(np.float32),
             "W": rng.standard_normal((8, 8)).astype(np.float32)}
        ids = [3, 0, 15, 7]

        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        # §3.1 data load: row-layout weights + input, then the conversion
        _insert_table(con, "W", (8, 2), w["W"].reshape(8, 2, 4))
        _insert_table(con, "vocab", (16, 2), w["vocab"].reshape(16, 2, 4))
        con.executemany("INSERT INTO ids VALUES (?, ?)",
                        [(t, float(i)) for t, i in enumerate(ids)])
        _run_statements(con, conv)
        _run_statements(con, rest)

        got = con.execute("SELECT t, c, v FROM y ORDER BY t, c").fetchall()
        out = np.zeros((4, 2, 4), np.float32)
        for t, c, v in got:
            out[t, c] = v
        ref = w["vocab"][ids] @ w["W"].T
        np.testing.assert_allclose(out.reshape(4, 8), ref, rtol=1e-4,
                                   atol=1e-4)
        # the conversion really produced the transposed table
        n_col_rows = con.execute("SELECT COUNT(*) FROM W__col").fetchone()[0]
        assert n_col_rows == 8 * 2  # (d, c) rows


class TestGoldenSQLAgainstDuckDB:
    """The pinned golden-SQL snapshots from test_planner must actually
    *run* on a real DuckDB and produce the transposed table — snapshots
    that only string-match can rot."""

    def test_chunk_conversion_snapshot_executes(self):
        from test_planner import (GOLDEN_CHUNK_CONVERSION_DUCKDB,
                                  GOLDEN_CHUNK_DDL_DUCKDB)
        from repro.core.sqlgen import UDF_PRELUDE_DUCKDB
        rng = np.random.default_rng(3)
        w = rng.standard_normal((8, 8)).astype(np.float32)
        con = duckdb.connect()
        _run_statements(con, _listify(UDF_PRELUDE_DUCKDB))
        _run_statements(con, _listify(
            "CREATE TABLE W (j INT32, c INT32, chunk FLOAT[2]);"))
        _run_statements(con, _listify(GOLDEN_CHUNK_DDL_DUCKDB))
        _insert_table(con, "W", (8, 4), w.reshape(8, 4, 2))
        _run_statements(con, _listify(GOLDEN_CHUNK_CONVERSION_DUCKDB))
        rows = con.execute(
            "SELECT d, c, chunk FROM W__col ORDER BY d, c").fetchall()
        assert len(rows) == 8  # (d ∈ [8), one 8-wide output chunk)
        got = np.stack([np.asarray(chunk, np.float32)
                        for _, _, chunk in rows])
        np.testing.assert_allclose(got, w.T, rtol=1e-6, atol=1e-6)

    def test_row2col_conversion_snapshot_executes(self):
        from test_planner import GOLDEN_CONVERSION_DUCKDB
        from repro.core.sqlgen import UDF_PRELUDE_DUCKDB
        rng = np.random.default_rng(4)
        w = rng.standard_normal((8, 8)).astype(np.float32)
        con = duckdb.connect()
        _run_statements(con, _listify(UDF_PRELUDE_DUCKDB))
        _run_statements(con, _listify(
            "CREATE TABLE W (j INT32, c INT32, chunk FLOAT[4]);"))
        _insert_table(con, "W", (8, 2), w.reshape(8, 2, 4))
        _run_statements(con, _listify(GOLDEN_CONVERSION_DUCKDB))
        rows = con.execute(
            "SELECT d, c, chunk FROM W__col ORDER BY d, c").fetchall()
        got = np.zeros((8, 2, 4), np.float32)
        for d, c, chunk in rows:
            got[d, c] = chunk
        np.testing.assert_allclose(got.reshape(8, 8), w.T, rtol=1e-6,
                                   atol=1e-6)


class TestDecodeStepEndToEnd:
    """One §3.4 decode step — layout-planned weights AND a re-laid-out KV
    cache — executed by DuckDB and compared against the JAX executor."""

    @pytest.mark.parametrize("cache_layout", ["row_chunk", "head_major"])
    def test_decode_step_matches_executor(self, cache_layout):
        g = build_decode_graph(SPEC, cache_len=4)
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe, layout_mode="col", cache_mode=cache_layout)
        params = init_llama_params(SPEC, seed=0)

        # -- executor reference
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC, 4, chunk_size=CS,
                                      layout=cache_layout))
        env["token_ids"] = token_table(np.asarray([5], np.int32))
        env["freq_each_token"] = rope_freq_table(np.asarray([0]),
                                                 SPEC.head_dim,
                                                 SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        ref = np.asarray(outs["logits"].cols["v"]).reshape(-1)[: SPEC.vocab]

        # -- DuckDB
        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        sql = re.sub(r":cache_position\b", "0", sql)
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        for name, arr in params.items():
            shaped = arr.reshape(*arr.shape[:-1], arr.shape[-1] // CS, CS) \
                if arr.shape[-1] >= CS else arr.reshape(*arr.shape[:-1], 1,
                                                        arr.shape[-1])
            _insert_table(con, name, shaped.shape[:-1], shaped)
        _insert_dense_tables(con, env, ["token_ids", "freq_each_token"])
        _run_statements(con, conv)
        _run_statements(con, rest)  # views + the KV-cache INSERTs

        got_rows = con.execute(
            "SELECT c, v FROM logits ORDER BY c").fetchall()
        got = np.concatenate([np.asarray(v, np.float32)
                              for _, v in got_rows])[: SPEC.vocab]
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)
        # the cache INSERT landed in the planner-chosen layout
        cols = [r[1] for r in con.execute(
            "PRAGMA table_info('k_cache_L0')").fetchall()]
        want_first = "hk" if cache_layout == "head_major" else "tp"
        assert cols[0] == want_first
        n = con.execute("SELECT COUNT(*) FROM k_cache_L0").fetchone()[0]
        assert n == SPEC.n_kv  # one position × n_kv heads × 1 chunk


class TestBatchedDecodeEndToEnd:
    """One *batched* §3.4 decode step (B > 1): the seq-keyed plan — seq-led
    cache DDL, the per-seq :seq_positions list parameter in the causal
    mask, and the batched INSERT computing each row's position — executed
    by a real DuckDB and compared against the JAX executor."""

    B = 2

    def test_batched_decode_step_matches_executor(self):
        g = build_decode_graph(SPEC, cache_len=4, batch=self.B)
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe)
        params = init_llama_params(SPEC, seed=0)
        toks = np.asarray([5, 11], np.int32)     # different token per seq
        positions = np.zeros(self.B, np.int64)

        # -- executor reference
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC, 4, chunk_size=CS, batch=self.B))
        env["token_ids"] = token_table(toks, key="seq")
        env["freq_each_token"] = rope_freq_table(positions, SPEC.head_dim,
                                                 SPEC.rope_theta, key="seq")
        outs, upd = run_pipeline(pipe, env,
                                 scalars={"seq_positions": positions})
        ref = np.asarray(outs["logits"].cols["v"]).reshape(
            self.B, -1)[:, : SPEC.vocab]

        # -- DuckDB: substitute the per-seq position list parameter
        sql = _listify(generate_sql(pipe, dialect="duckdb"))
        pos_lit = "[" + ", ".join(str(int(p)) for p in positions) + "]"
        sql = re.sub(r":seq_positions\b", pos_lit, sql)
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        for name, arr in params.items():
            shaped = arr.reshape(*arr.shape[:-1], arr.shape[-1] // CS, CS) \
                if arr.shape[-1] >= CS else arr.reshape(*arr.shape[:-1], 1,
                                                        arr.shape[-1])
            _insert_table(con, name, shaped.shape[:-1], shaped)
        _insert_dense_tables(con, env, ["token_ids", "freq_each_token"])
        _run_statements(con, conv)
        _run_statements(con, rest)  # views + the batched KV-cache INSERTs

        got_rows = con.execute(
            "SELECT seq, c, v FROM logits ORDER BY seq, c").fetchall()
        got = np.zeros((self.B, -(-SPEC.vocab // CS) * CS), np.float32)
        for s, c, v in got_rows:
            got[s, c * CS:(c + 1) * CS] = v
        np.testing.assert_allclose(got[:, : SPEC.vocab], ref, rtol=1e-3,
                                   atol=1e-3)
        # the batched INSERT landed per sequence at its own position
        cols = [r[1] for r in con.execute(
            "PRAGMA table_info('k_cache_L0')").fetchall()]
        assert cols[0] == "seq" and cols[1] == "tp"
        rows = con.execute(
            "SELECT seq, tp, COUNT(*) FROM k_cache_L0 GROUP BY seq, tp "
            "ORDER BY seq").fetchall()
        assert rows == [(0, 0, SPEC.n_kv), (1, 0, SPEC.n_kv)]
        # per-seq logits differ (the two sequences decoded different
        # tokens through ONE plan)
        assert not np.allclose(got[0], got[1])


class TestQuantisedDecodeEndToEnd:
    """One §3.4 decode step with quantised chunk payloads (ISSUE 5): the
    quantised DDL, the f32 → int8 quantisation conversion and the inline
    dequant-projection views executed by a *real* DuckDB, compared against
    the JAX executor running the same quantised pipeline."""

    def _pipe(self, precision="int8", layout_mode="off"):
        g = build_decode_graph(SPEC, cache_len=4)
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe, layout_mode=layout_mode,
                     precision_mode=precision)
        return pipe

    def test_quantised_decode_step_matches_executor(self):
        pipe = self._pipe("int8")
        params = init_llama_params(SPEC, seed=0)

        # -- executor reference (same quantised pipeline)
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC, 4, chunk_size=CS))
        env["token_ids"] = token_table(np.asarray([5], np.int32))
        env["freq_each_token"] = rope_freq_table(np.asarray([0]),
                                                 SPEC.head_dim,
                                                 SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        ref = np.asarray(outs["logits"].cols["v"]).reshape(-1)[: SPEC.vocab]

        # -- DuckDB: load f32 sources, quantise IN SQL, run the views
        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        assert "precision: int8 (planner)" in sql
        sql = re.sub(r":cache_position\b", "0", sql)
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        for name, arr in params.items():
            shaped = arr.reshape(*arr.shape[:-1], arr.shape[-1] // CS, CS) \
                if arr.shape[-1] >= CS else arr.reshape(*arr.shape[:-1], 1,
                                                        arr.shape[-1])
            _insert_table(con, name, shaped.shape[:-1], shaped)
        _insert_dense_tables(con, env, ["token_ids", "freq_each_token"])
        _run_statements(con, conv)
        _run_statements(con, rest)

        got_rows = con.execute(
            "SELECT c, v FROM logits ORDER BY c").fetchall()
        got = np.concatenate([np.asarray(v, np.float32)
                              for _, v in got_rows])[: SPEC.vocab]
        # SQL quantises in double precision (DuckDB) while the executor
        # quantises in f32, so a code may flip at a rounding boundary —
        # each flip moves one weight by one scale step, hence the looser
        # tolerance than the f32 e2e comparisons
        np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)
        # the quantised tables really exist and store integer codes
        n = con.execute("SELECT COUNT(*) FROM lm_head__int8").fetchone()[0]
        assert n == SPEC.vocab * (SPEC.d_model // CS)
        cols = {r[1]: r[2] for r in con.execute(
            "PRAGMA table_info('lm_head__int8')").fetchall()}
        assert cols["qchunk"].startswith("TINYINT")
        assert cols["scale"].startswith("FLOAT")

    def test_sql_and_jax_quantise_identically(self):
        """The SQL encode (round / nf4_encode macro) and the JAX reference
        kernel produce the same codes and scales on real weight data —
        up to double-vs-float scale rounding at code boundaries."""
        from repro.core.sqlgen import UDF_PRELUDE_DUCKDB
        from repro.quant import CODECS, UDF_PRELUDE_QUANT_DUCKDB
        from repro.quant.sql import quantise_conversion_sql
        rng = np.random.default_rng(7)
        w = rng.standard_normal((8, 2, 4)).astype(np.float32)
        con = duckdb.connect()
        _run_statements(con, _listify(UDF_PRELUDE_DUCKDB))
        _run_statements(con, _listify(UDF_PRELUDE_QUANT_DUCKDB))
        _run_statements(con, _listify(
            "CREATE TABLE W (j INT32, c INT32, chunk FLOAT[4]);"))
        _insert_table(con, "W", (8, 2), w)
        for precision in ("int8", "nf4"):
            _run_statements(con, _listify(quantise_conversion_sql(
                "W", f"W__{precision}", precision, ("j", "c"), "chunk")))
            rows = con.execute(
                f"SELECT j, c, qchunk, scale FROM W__{precision} "
                f"ORDER BY j, c").fetchall()
            codec = CODECS[precision]
            codes_ref, scales_ref = codec.quantise(w)
            codes_ref = np.asarray(codes_ref)
            scales_ref = np.asarray(scales_ref)
            n_boundary = 0
            for j, c, q, s in rows:
                np.testing.assert_allclose(s, scales_ref[j, c], rtol=1e-5)
                diff = np.abs(np.asarray(q, np.int64)
                              - codes_ref[j, c].astype(np.int64))
                n_boundary += int((diff > 0).sum())
                assert diff.max() <= 1  # only boundary flips allowed
            assert n_boundary <= 2  # essentially never on random data


class TestChunkAutoDecodeEndToEnd:
    """Acceptance: a decode step under per-table (layout, chunk_size)
    planning is numerically equivalent to the fixed-chunk baseline in
    DuckDB too — the chunk-annotated DDL, the re-chunk-tail views and the
    chunk-size-aware conversion SQL all execute for real."""

    def test_chunk_auto_decode_matches_executor(self):
        g = build_decode_graph(SPEC, cache_len=4)
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe, layout_mode="col", chunk_mode="auto",
                     chunk_candidates=(4, 8, 16))
        # the planner exercised its chunk freedom somewhere
        assert any(cs != CS for cs in pipe.table_chunks.values())
        params = init_llama_params(SPEC, seed=0)

        # -- executor reference (same planned pipeline)
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC, 4, chunk_size=CS))
        env["token_ids"] = token_table(np.asarray([5], np.int32))
        env["freq_each_token"] = rope_freq_table(np.asarray([0]),
                                                 SPEC.head_dim,
                                                 SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        ref = np.asarray(outs["logits"].cols["v"]).reshape(-1)[: SPEC.vocab]
        # and the fixed-chunk baseline for the end-to-end equivalence claim
        g2 = build_decode_graph(SPEC, cache_len=4)
        infer_shapes(g2)
        preoptimize(g2)
        pipe_base = op_map(g2, chunk_size=CS)
        env_b = convert_weights(params, chunk_size=CS)
        env_b.update(empty_cache_tables(SPEC, 4, chunk_size=CS))
        env_b["token_ids"] = token_table(np.asarray([5], np.int32))
        env_b["freq_each_token"] = env["freq_each_token"]
        outs_b, _ = run_pipeline(pipe_base, env_b,
                                 scalars={"cache_position": 0})
        base = np.asarray(outs_b["logits"].cols["v"]).reshape(-1)[
            : SPEC.vocab]
        np.testing.assert_allclose(ref, base, rtol=1e-4, atol=1e-4)

        # -- DuckDB
        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        assert "(planner)" in sql  # chunk-size-annotated DDL made it out
        sql = re.sub(r":cache_position\b", "0", sql)
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        for name, arr in params.items():
            shaped = arr.reshape(*arr.shape[:-1], arr.shape[-1] // CS, CS) \
                if arr.shape[-1] >= CS else arr.reshape(*arr.shape[:-1], 1,
                                                        arr.shape[-1])
            _insert_table(con, name, shaped.shape[:-1], shaped)
        _insert_dense_tables(con, env_b, ["token_ids", "freq_each_token"])
        _run_statements(con, conv)
        _run_statements(con, rest)

        got_rows = con.execute(
            "SELECT c, v FROM logits ORDER BY c").fetchall()
        got = np.concatenate([np.asarray(v, np.float32)
                              for _, v in got_rows])[: SPEC.vocab]
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


class TestTracedDecodeEndToEnd:
    """ISSUE 6 tentpole, closed loop: a decode tick executed statement by
    statement under DuckDB's JSON profiler (EXPLAIN ANALYSE payload),
    with every operator's wall time attributed back to the generating
    pipeline step via StatementProvenance — then fed straight into the
    cost model's drift report.  Bind steps are materialised
    (``step_create="TABLE"``) so each step's scan/join/aggregate work is
    profiled at its own statement, not lazily at the final SELECT."""

    def test_traced_decode_attributes_steps(self, tmp_path):
        from repro.core.sqlgen import generate_sql_with_provenance
        from repro.obs import (drift_report, run_statements, run_traced,
                               substitute_params)
        from repro.planner.calibrate import step_features

        g = build_decode_graph(SPEC, cache_len=4)
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe, layout_mode="col", cache_mode="auto")
        params = init_llama_params(SPEC, seed=0)

        # -- executor reference (correctness must survive tracing)
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC, 4, chunk_size=CS))
        env["token_ids"] = token_table(np.asarray([5], np.int32))
        env["freq_each_token"] = rope_freq_table(np.asarray([0]),
                                                 SPEC.head_dim,
                                                 SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        ref = np.asarray(outs["logits"].cols["v"]).reshape(-1)[: SPEC.vocab]

        pairs = [(substitute_params(_listify(sql), {"cache_position": 0}),
                  prov)
                 for sql, prov in generate_sql_with_provenance(
                     pipe, dialect="duckdb", include_conversion=True,
                     step_create="TABLE")]
        setup = [p for p in pairs if p[1].kind in
                 ("prelude", "comment", "ddl")]
        conv = [p for p in pairs if p[1].kind == "conversion"]
        tick_stmts = [p for p in pairs if p[1].kind in ("bind", "append")]
        assert len(setup) + len(conv) + len(tick_stmts) == len(pairs)

        con = duckdb.connect()
        run_statements(con, setup)
        for name, arr in params.items():
            shaped = arr.reshape(*arr.shape[:-1], arr.shape[-1] // CS, CS) \
                if arr.shape[-1] >= CS else arr.reshape(*arr.shape[:-1], 1,
                                                        arr.shape[-1])
            _insert_table(con, name, shaped.shape[:-1], shaped)
        _insert_dense_tables(con, env, ["token_ids", "freq_each_token"])
        run_statements(con, conv)

        tick = run_traced(con, tick_stmts)

        got_rows = con.execute(
            "SELECT c, v FROM logits ORDER BY c").fetchall()
        got = np.concatenate([np.asarray(v, np.float32)
                              for _, v in got_rows])[: SPEC.vocab]
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)

        # -- attribution: >=90% of profiled operator time lands on a
        #    named pipeline step (the ISSUE acceptance bar)
        step_names = {s.name for s in pipe.steps}
        times = tick.step_times_us()
        assert set(times) <= step_names
        assert len(times) == len(step_names)  # every step saw DB work
        assert tick.coverage() >= 0.9
        # the op-class rollup saw real relational work, incl. the §3.4
        # cache append
        classes = tick.class_times_us()
        assert "scan" in classes and "cache_append" in classes

        # -- drift report from the same tick: predicted cost features vs
        #    observed per-step DB time
        feats = step_features(SPEC, "decode", 1, CS, "col", cache_len=4)
        rep = drift_report(feats, times)
        assert {s.step for s in rep.steps} == set(feats)
        assert rep.total_observed_us == pytest.approx(sum(times.values()))
        assert rep.scale_us > 0

        # -- artifacts (CI uploads these from OBS_ARTIFACT_DIR)
        out = os.environ.get("OBS_ARTIFACT_DIR") or str(tmp_path)
        os.makedirs(out, exist_ok=True)
        tick.save_chrome(os.path.join(out, "decode_tick_trace.json"))
        tick.save_json(os.path.join(out, "decode_tick_attribution.json"))
        rep.save_json(os.path.join(out, "decode_tick_drift.json"))
        for f in ("decode_tick_trace.json", "decode_tick_attribution.json",
                  "decode_tick_drift.json"):
            assert os.path.getsize(os.path.join(out, f)) > 0


# wide enough that matmul sites pass the shard pricer (the module SPEC's
# 8-wide weights are refused: combine overhead exceeds the split saving)
SPEC_SHARD = LlamaSpec(vocab=64, d_model=32, n_layers=1, n_heads=4,
                       n_kv=2, d_ff=64, rope_theta=10000.0)


class TestShardedDecodeEndToEnd:
    """ISSUE 7 tentpole, closed loop: an N=2 sharded decode plan — the
    per-shard key-range slice conversion, per-shard partial views and the
    combine relations (key-disjoint UNION and UNION ALL + SUM) — executes
    on a real DuckDB and reproduces the JAX executor's logits."""

    N = 2

    def test_sharded_decode_step_matches_executor(self):
        from repro.planner.shard import plan_shards
        g = build_decode_graph(SPEC_SHARD, cache_len=4)
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=CS)
        postoptimize(pipe, layout_mode="col")
        plan = plan_shards(pipe, self.N)
        assert plan.decisions  # the pricer admitted sites on this spec
        params = init_llama_params(SPEC_SHARD, seed=0)

        # -- executor reference: the plans are not rewritten, so running
        #    the same pipeline without a shard_runner IS the unsharded
        #    baseline the SQL must match
        env = convert_weights(params, chunk_size=CS)
        env.update(empty_cache_tables(SPEC_SHARD, 4, chunk_size=CS))
        env["token_ids"] = token_table(np.asarray([5], np.int32))
        env["freq_each_token"] = rope_freq_table(np.asarray([0]),
                                                 SPEC_SHARD.head_dim,
                                                 SPEC_SHARD.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        ref = np.asarray(outs["logits"].cols["v"]).reshape(-1)[
            : SPEC_SHARD.vocab]

        # -- DuckDB: shard slices ride in the conversion section
        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        assert "-- SHARD data conversion" in sql
        sql = re.sub(r":cache_position\b", "0", sql)
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        for name, arr in params.items():
            shaped = arr.reshape(*arr.shape[:-1], arr.shape[-1] // CS, CS) \
                if arr.shape[-1] >= CS else arr.reshape(*arr.shape[:-1], 1,
                                                        arr.shape[-1])
            _insert_table(con, name, shaped.shape[:-1], shaped)
        _insert_dense_tables(con, env, ["token_ids", "freq_each_token"])
        _run_statements(con, conv)
        _run_statements(con, rest)  # per-shard views, combines, tails

        got_rows = con.execute(
            "SELECT c, v FROM logits ORDER BY c").fetchall()
        got = np.concatenate([np.asarray(v, np.float32)
                              for _, v in got_rows])[: SPEC_SHARD.vocab]
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)

        # every decision's slice tables exist at their local sizes
        for dec in plan.decisions:
            schema = dec.scan.table_schema
            for s, (lo, hi) in enumerate(dec.ranges):
                n = con.execute(
                    "SELECT COUNT(*) FROM "
                    + dec.shard_table(s).replace("::", "__")).fetchone()[0]
                want = 1
                for k, sz in schema.keys:
                    want *= (hi - lo) if k == dec.axis else sz
                assert n == want
        # the sharded steps' tails read the combine relations
        assert "__combine" in rest


class TestGoldenShardSQLAgainstDuckDB:
    """The pinned per-shard golden snapshots from test_shard must *run*:
    the sliced tables, partial views and the concat combine reproduce the
    unsharded matmul numerically."""

    def test_golden_col_shard_script_executes(self):
        from test_shard import _linear_pipe
        from repro.planner import plan_layouts
        from repro.planner.shard import plan_shards
        pipe = _linear_pipe(d=32)
        plan_layouts(pipe, mode="col")
        plan_shards(pipe, 2)
        rng = np.random.default_rng(0)
        w = {"vocab": rng.standard_normal((16, 32)).astype(np.float32),
             "W": rng.standard_normal((32, 32)).astype(np.float32)}
        ids = [3, 0, 15, 7]

        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        ddl, conv, rest = _split_script(sql)
        con = duckdb.connect()
        _run_statements(con, ddl)
        _insert_table(con, "W", (32, 8), w["W"].reshape(32, 8, 4))
        _insert_table(con, "vocab", (16, 8), w["vocab"].reshape(16, 8, 4))
        con.executemany("INSERT INTO ids VALUES (?, ?)",
                        [(t, float(i)) for t, i in enumerate(ids)])
        _run_statements(con, conv)
        _run_statements(con, rest)

        # each shard slice holds half the output-chunk ranges
        for s, (lo, hi) in ((0, (0, 4)), (1, (4, 8))):
            n = con.execute(
                f"SELECT COUNT(*) FROM W__col__shard{s}").fetchone()[0]
            assert n == 32 * (hi - lo)
        got = con.execute("SELECT t, c, v FROM y ORDER BY t, c").fetchall()
        out = np.zeros((4, 8, 4), np.float32)
        for t, c, v in got:
            out[t, c] = v
        ref = w["vocab"][ids] @ w["W"].T
        np.testing.assert_allclose(out.reshape(4, 32), ref, rtol=1e-4,
                                   atol=1e-4)

    def test_golden_row_shard_combine_executes(self):
        """Row-parallel flavour: each shard owns half the reduction
        chunks, the combine is UNION ALL + per-group SUM of the partial
        sums.  (The decode plan above only admits col/colh sites, so the
        SUM combine gets its own execution here.)"""
        from test_shard import _linear_pipe
        from repro.planner.shard import plan_shards
        pipe = _linear_pipe(d=32)
        (dec,) = plan_shards(pipe, 2).decisions
        assert dec.kind == "row"
        rng = np.random.default_rng(1)
        w = {"vocab": rng.standard_normal((16, 32)).astype(np.float32),
             "W": rng.standard_normal((32, 32)).astype(np.float32)}
        ids = [1, 9, 2, 14]

        sql = _listify(generate_sql(pipe, dialect="duckdb",
                                    include_conversion=True))
        # no ROW2COL section here: split at the shard conversion instead
        # (the slices must run AFTER the row tables are loaded)
        i = sql.index("-- SHARD data conversion")
        j = sql.index("CREATE OR REPLACE VIEW")
        con = duckdb.connect()
        _run_statements(con, sql[:i])
        _insert_table(con, "W", (32, 8), w["W"].reshape(32, 8, 4))
        _insert_table(con, "vocab", (16, 8), w["vocab"].reshape(16, 8, 4))
        con.executemany("INSERT INTO ids VALUES (?, ?)",
                        [(t, float(i_)) for t, i_ in enumerate(ids)])
        _run_statements(con, sql[i:j])
        _run_statements(con, sql[j:])

        # the partials are half-sums, the combine restores the matmul
        got = con.execute("SELECT t, c, v FROM y ORDER BY t, c").fetchall()
        out = np.zeros((4, 8, 4), np.float32)
        for t, c, v in got:
            out[t, c] = v
        ref = w["vocab"][ids] @ w["W"].T
        np.testing.assert_allclose(out.reshape(4, 32), ref, rtol=1e-4,
                                   atol=1e-4)
        half = con.execute("SELECT COUNT(*) FROM W__shard0").fetchone()[0]
        assert half == 32 * 4  # j × half the reduction chunks


class TestPrefixSegmentSQLEndToEnd:
    """ISSUE 9: the prefix-cache segment-bind statements executed on a
    real DuckDB — the share-mode remap view composes segment + slot rows
    exactly at the prefix boundary, the copy-mode ``INSERT ... SELECT``
    lands the shared rows in the slot, and both dialects emit
    byte-identical (pinned) SQL."""

    GOLDEN_REMAP = """\
CREATE OR REPLACE VIEW k_cache_L0__seq1 AS
-- prefix-segment remap: shared rows [0, 3) re-keyed to seq = 1
SELECT 1 AS seq, tp, hk, c, kv FROM k_cache_L0__seg WHERE tp < 3
UNION ALL
SELECT seq, tp, hk, c, kv FROM k_cache_L0 WHERE seq = 1 AND tp >= 3;"""

    GOLDEN_COPY = """\
-- prefix-segment bulk copy (copy-mode bind)
INSERT INTO k_cache_L0 (seq, tp, hk, c, kv)
SELECT 1 AS seq, tp, hk, c, kv FROM k_cache_L0__seg WHERE tp < 3;"""

    def _schema(self):
        env = empty_cache_tables(SPEC, 6, chunk_size=CS, batch=2)
        return env["k_cache_L0"].schema()

    def test_dialects_emit_identical_golden_sql(self):
        from repro.core.sqlgen import (segment_copy_sql,
                                       segment_remap_view_sql)
        sch = self._schema()
        for dialect in ("duckdb", "ansi"):
            assert segment_remap_view_sql(
                "k_cache_L0__seq1", "k_cache_L0", "k_cache_L0__seg",
                1, 3, sch, dialect=dialect) == self.GOLDEN_REMAP
            assert segment_copy_sql(
                "k_cache_L0", "k_cache_L0__seg", 1, 3, sch,
                dialect=dialect) == self.GOLDEN_COPY

    def test_remap_view_and_copy_execute(self):
        from repro.core.sqlgen import (segment_copy_sql,
                                       segment_remap_view_sql)
        sch = self._schema()
        con = duckdb.connect()
        _run_statements(con, _listify(
            "CREATE TABLE k_cache_L0 (seq INT32, tp INT32, hk INT32, "
            "c INT32, kv FLOAT[4]);"
            "CREATE TABLE k_cache_L0__seg (tp INT32, hk INT32, c INT32, "
            "kv FLOAT[4]);"))
        # segment rows carry 100 + tp, the slot's own rows 200 + tp, so
        # every output row names its source
        con.executemany(
            "INSERT INTO k_cache_L0__seg VALUES (?, ?, ?, ?)",
            [(tp, 0, 0, [100.0 + tp] * CS) for tp in range(6)])
        con.executemany(
            "INSERT INTO k_cache_L0 VALUES (?, ?, ?, ?, ?)",
            [(1, tp, 0, 0, [200.0 + tp] * CS) for tp in range(6)])

        _run_statements(con, segment_remap_view_sql(
            "k_cache_L0__seq1", "k_cache_L0", "k_cache_L0__seg", 1, 3,
            sch))
        got = con.execute("SELECT seq, tp, kv FROM k_cache_L0__seq1 "
                          "ORDER BY tp").fetchall()
        assert [r[0] for r in got] == [1] * 6      # every row re-keyed
        # the splice: segment rows below the boundary, slot rows above
        assert [r[2][0] for r in got] == [100.0, 101.0, 102.0,
                                          203.0, 204.0, 205.0]

        # copy-mode bind: the shared rows land in seq 0's empty slot
        _run_statements(con, segment_copy_sql(
            "k_cache_L0", "k_cache_L0__seg", 0, 3, sch))
        rows = con.execute("SELECT tp, kv FROM k_cache_L0 WHERE seq = 0 "
                           "ORDER BY tp").fetchall()
        assert [(tp, kv[0]) for tp, kv in rows] == [
            (0, 100.0), (1, 101.0), (2, 102.0)]
