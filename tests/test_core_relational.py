"""Unit tests for the relational core: chunked tables, operator mapping,
executor semantics, optimisation passes, SQL generation."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.chunked import ChunkedTensor, rechunk
from repro.core import relational as ra
from repro.core.executor import DenseTable, execute, table_from_chunked
from repro.core.graph import Graph, infer_shapes
from repro.core.opmap import op_map
from repro.core.passes import (constant_fold, dead_code_elim,
                               eliminate_shape_ops, fuse_projections)
from repro.core.relational import (
    Collect, Filter, GroupAgg, Join, Project, Scan, Unnest, add, call, col,
    const, div, floordiv, key, mod, mul, resolve, sub, SCALAR, VEC,
)
from repro.core.sqlgen import SQLGenerator, generate_sql


def _table(name, arr, cs=8):
    return table_from_chunked(ChunkedTensor.from_dense(name, arr,
                                                       chunk_size=cs))


class TestChunked:
    def test_roundtrip(self):
        x = np.random.default_rng(0).standard_normal((5, 20)).astype(np.float32)
        ct = ChunkedTensor.from_dense("t", x, chunk_size=8)
        assert ct.data.shape == (5, 3, 8)  # padded to 3 chunks
        np.testing.assert_array_equal(np.asarray(ct.to_dense()), x)

    def test_rechunk(self):
        x = np.arange(48, dtype=np.float32).reshape(3, 16)
        ct = ChunkedTensor.from_dense("t", x, chunk_size=8)
        ct2 = rechunk(ct, 4)
        assert ct2.data.shape == (3, 4, 4)
        np.testing.assert_array_equal(np.asarray(ct2.to_dense()), x)

    def test_ddl_and_insert(self):
        x = np.ones((2, 4), np.float32)
        ct = ChunkedTensor.from_dense("w", x, chunk_size=4)
        ddl = ct.schema.ddl()
        assert "CREATE TABLE w" in ddl and "FLOAT[4]" in ddl
        ins = ct.insert_sql()
        assert ins.count("INSERT INTO w") == 2

    def test_table_rows_match_paper_format(self):
        x = np.arange(8, dtype=np.float32).reshape(2, 4)
        ct = ChunkedTensor.from_dense("w", x, chunk_size=2)
        rows = ct.as_table_rows()
        # rows are (i, c, w_i^{(c)})
        i, c, vec = rows[0]
        assert (i, c) == (0, 0)
        np.testing.assert_array_equal(vec, [0.0, 1.0])


class TestExecutor:
    def test_matmul_join_groupagg(self):
        rng = np.random.default_rng(1)
        x = rng.standard_normal((6, 16)).astype(np.float32)
        w = rng.standard_normal((10, 16)).astype(np.float32)
        xt, wt = _table("x", x), _table("w", w)
        plan = GroupAgg(
            input=Join(left=Scan("x", xt.schema()), right=Scan("w", wt.schema()),
                       on=[("chunk_id", key("chunk_id"))]),
            group_keys=["row_id", "row_id_r"],
            aggs=[("s", "SUM", call("dot", col("chunk"), col("chunk_r")))])
        # rename right row key to avoid collision
        wt2 = DenseTable(keys=(("row_id_r", 10), ("chunk_id", 2)),
                         cols=wt.cols, col_types=wt.col_types)
        plan = GroupAgg(
            input=Join(left=Scan("x", xt.schema()),
                       right=Scan("w", wt2.schema()),
                       on=[("chunk_id", key("chunk_id"))]),
            group_keys=["row_id", "row_id_r"],
            aggs=[("s", "SUM", call("dot", col("chunk"), col("chunk_r")))])
        out = execute(plan, {"x": xt, "w": wt2})
        np.testing.assert_allclose(np.asarray(out.cols["s"]), x @ w.T,
                                   rtol=1e-5, atol=1e-5)

    def test_gqa_style_join_expr(self):
        """Join with right key = left_key // g (paper Tab. 2 GQA join)."""
        q = np.random.default_rng(2).standard_normal((4, 8)).astype(np.float32)
        kv = np.random.default_rng(3).standard_normal((2, 8)).astype(np.float32)
        qt = DenseTable(keys=(("h", 4),), cols={"q": jnp.asarray(q)},
                        col_types={"q": VEC(8)})
        kt = DenseTable(keys=(("hk", 2),), cols={"k": jnp.asarray(kv)},
                        col_types={"k": VEC(8)})
        plan = GroupAgg(
            input=Join(left=Scan("q", qt.schema()), right=Scan("k", kt.schema()),
                       on=[("hk", floordiv(key("h"), const(2)))]),
            group_keys=["h"],
            aggs=[("s", "SUM", call("dot", col("q"), col("k")))])
        out = execute(plan, {"q": qt, "k": kt})
        want = np.array([q[h] @ kv[h // 2] for h in range(4)])
        np.testing.assert_allclose(np.asarray(out.cols["s"]), want, rtol=1e-5)

    def test_filter_masks_with_identity(self):
        t = DenseTable(keys=(("t", 3), ("tp", 3)),
                       cols={"s": jnp.ones((3, 3))},
                       col_types={"s": SCALAR})
        plan = Filter(input=Scan("t", t.schema()),
                      predicate=("<=", key("tp"), key("t")),
                      masked_value=0.0)
        out = execute(plan, {"t": t})
        np.testing.assert_array_equal(np.asarray(out.cols["s"]),
                                      np.tril(np.ones((3, 3))))

    def test_unnest_collect_roundtrip(self):
        x = np.arange(12, dtype=np.float32).reshape(3, 4)
        t = DenseTable(keys=(("r", 3),), cols={"v": jnp.asarray(x)},
                       col_types={"v": VEC(4)})
        u = Unnest(input=Scan("t", t.schema()), vec_col="v")
        c = Collect(input=u, fold_key="e", scalar_col="x", vec_col="v")
        out = execute(c, {"t": t})
        np.testing.assert_array_equal(np.asarray(out.cols["v"]), x)

    def test_project_key_split_merge(self):
        x = np.arange(24, dtype=np.float32)
        t = DenseTable(keys=(("i", 24),), cols={"v": jnp.asarray(x)},
                       col_types={"v": SCALAR})
        split = Project(input=Scan("t", t.schema()),
                        keys=[("a", 4, floordiv(key("i"), const(6))),
                              ("b", 6, mod(key("i"), const(6)))],
                        exprs=[("v", None, col("v"))])
        out = execute(split, {"t": t})
        np.testing.assert_array_equal(np.asarray(out.cols["v"]),
                                      x.reshape(4, 6))
        merge = Project(input=split,
                        keys=[("i", 24, add(mul(key("a"), const(6)),
                                            key("b")))],
                        exprs=[("v", None, col("v"))])
        out2 = execute(merge, {"t": t})
        np.testing.assert_array_equal(np.asarray(out2.cols["v"]), x)

    def test_value_join_embedding(self):
        ids = DenseTable(keys=(("t", 3),),
                         cols={"s": jnp.asarray([2, 0, 1])},
                         col_types={"s": SCALAR})
        vocab = _table("vocab", np.eye(3, 8, dtype=np.float32), cs=8)
        plan = Project(
            input=Join(left=Scan("ids", ids.schema()),
                       right=Scan("vocab", vocab.schema()),
                       on=[("row_id", col("s"))]),
            keys=None, exprs=[("v", None, col("chunk"))])
        out = execute(plan, {"ids": ids, "vocab": vocab})
        arr = np.asarray(out.cols["v"])[:, 0, :]
        np.testing.assert_array_equal(arr, np.eye(3, 8)[[2, 0, 1]])


class TestPasses:
    def _proj_chain(self):
        t = DenseTable(keys=(("i", 4),), cols={"v": jnp.arange(4.0)},
                       col_types={"v": SCALAR})
        inner = Project(input=Scan("t", t.schema()), keys=None,
                        exprs=[("a", None, mul(col("v"), const(2.0)))])
        outer = Project(input=inner, keys=None,
                        exprs=[("b", None, add(col("a"), const(1.0)))])
        return t, outer

    def test_fuse_projections(self):
        t, outer = self._proj_chain()
        fused = fuse_projections(outer)
        assert isinstance(fused.input, Scan)  # π∘π collapsed
        out = execute(fused, {"t": t})
        np.testing.assert_array_equal(np.asarray(out.cols["b"]),
                                      np.arange(4.0) * 2 + 1)

    def test_constant_fold_and_dce(self):
        g = Graph(name="g")
        g.constants["two"] = 2.0
        g.constants["three"] = 3.0
        g.add("mul", ["two", "three"], output="six")
        g.add("identity", ["x"], output="y")
        g.add("identity", ["y"], output="z")
        g.inputs = ["x"]
        g.outputs = ["z"]
        n_folded = constant_fold(g)
        assert n_folded == 1 and g.constants["six"] == 6.0
        removed = eliminate_shape_ops(g)
        assert removed == 2 and g.outputs == ["x"]
        assert dead_code_elim(g) == 0


class TestSQLGen:
    def test_matmul_sql_shape(self):
        """The emitted SQL for a linear op matches the paper's §2.1 pattern:
        JOIN on chunk index + SUM(dot) + GROUP BY free dims."""
        g = Graph(name="lin")
        g.inputs = ["ids"]
        g.annotate("ids", (("t", 4),))
        g.annotate("vocab", (("tok", 16), ("d", 8)))
        g.initializers["vocab"] = None
        g.initializers["W"] = None
        g.annotate("W", (("j", 8), ("d", 8)))
        x = g.add("embedding", ["vocab", "ids"])
        g.add("linear", [x, "W"], out_features=8, output="y")
        g.outputs = ["y"]
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        sql = generate_sql(pipe, dialect="duckdb")
        assert "JOIN" in sql and "GROUP BY" in sql
        assert "list_dot_product" in sql
        assert "collect_as_array" in sql
        assert "CREATE TABLE W" in sql
        # ANSI dialect also renders
        sql2 = generate_sql(pipe, dialect="ansi")
        assert "dot(" in sql2

    def test_param_placeholder(self):
        from repro.core.relational import Param
        from repro.core.relational import RelSchema
        sch = RelSchema(keys=(("t", 4),), cols=(("s", SCALAR),))
        gen = SQLGenerator.__new__(SQLGenerator)
        gen.dialect = "duckdb"
        out = gen.render_expr(add(key("t"), Param("cache_position")), sch)
        assert ":cache_position" in out
