"""Planner subsystem tests: layout legality, cost model, ROW2COL rewrite
equivalence (executor path, prefill + decode), golden SQL snapshots for
both dialects, and the serving-engine knob."""

import numpy as np
import pytest

from repro.core.executor import (col_table_from_dense, execute,
                                 table_from_chunked, transpose_chunked_table)
from repro.core.chunked import ChunkedTensor
from repro.core.graph import Graph, infer_shapes
from repro.core.llama_graph import (LlamaSpec, build_decode_graph,
                                    build_prefill_graph, convert_weights,
                                    empty_cache_tables, init_llama_params,
                                    rope_freq_table, token_table)
from repro.core.opmap import op_map
from repro.core.passes import postoptimize, preoptimize
from repro.core.pipeline import run_pipeline
from repro.core.sqlgen import SQLGenerator, generate_sql
from repro.planner import (COL_CHUNK, ROW_CHUNK, CostParams,
                           admissible_layouts, choose_layout,
                           col_chunk_cost, match_matmul_site, plan_layouts,
                           row_chunk_cost)

SPEC = LlamaSpec(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=2,
                 d_ff=64, rope_theta=10000.0)


def _linear_pipe(cs=4):
    """Tiny embedding→linear pipeline (the canonical map_linear site)."""
    g = Graph(name="lin")
    g.inputs = ["ids"]
    g.annotate("ids", (("t", 4),))
    g.annotate("vocab", (("tok", 16), ("d", 8)))
    g.initializers["vocab"] = None
    g.initializers["W"] = None
    g.annotate("W", (("j", 8), ("d", 8)))
    x = g.add("embedding", ["vocab", "ids"])
    g.add("linear", [x, "W"], out_features=8, output="y")
    g.outputs = ["y"]
    infer_shapes(g)
    return op_map(g, chunk_size=cs)


def _linear_env(cs=4, seed=0):
    rng = np.random.default_rng(seed)
    w = {"vocab": rng.standard_normal((16, 8)).astype(np.float32),
         "W": rng.standard_normal((8, 8)).astype(np.float32)}
    env = convert_weights(w, chunk_size=cs)
    env["ids"] = token_table(np.asarray([3, 0, 15, 7], np.int32))
    return w, env


class TestLayoutIR:
    def test_match_linear_site(self):
        pipe = _linear_pipe()
        site = match_matmul_site("y", pipe.bindings["y"].plan)
        assert site is not None
        assert site.table == "W"
        assert site.in_features == 8 and site.out_features == 8
        assert admissible_layouts(site) == (ROW_CHUNK, COL_CHUNK)

    def test_per_head_and_embedding_not_admissible(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        matched = {match_matmul_site(s.name, s.rel.plan).table
                   for s in pipe.steps if s.kind == "bind"
                   and match_matmul_site(s.name, s.rel.plan) is not None}
        # only the two-key map_linear weights are legal COL_CHUNK sites
        assert "o_weights_L0" in matched and "lm_head" in matched
        assert not any(t.startswith(("Q_", "K_", "V_")) for t in matched)
        assert "vocabulary" not in matched
        assert admissible_layouts(None) == (ROW_CHUNK,)

    def test_transpose_roundtrip(self):
        w = np.arange(32, dtype=np.float32).reshape(8, 4)
        row = table_from_chunked(ChunkedTensor.from_dense("w", w, chunk_size=2))
        col = transpose_chunked_table(row, col_chunk=4)
        assert col.keys == (("d", 4), ("c", 2))
        # col table holds Wᵀ chunked over the output dim
        np.testing.assert_array_equal(
            np.asarray(col.cols["chunk"]).reshape(4, 8), w.T)
        direct = col_table_from_dense(w, col_chunk=4)
        np.testing.assert_array_equal(np.asarray(direct.cols["chunk"]),
                                      np.asarray(col.cols["chunk"]))


class TestCostModel:
    def test_col_avoids_reduction_key_explosion(self):
        """COL_CHUNK's GROUP BY cardinality is cs× smaller than ROW_CHUNK's
        and it pays no re-chunk tail."""
        row = row_chunk_cost(T=4, in_f=64, out_f=64, cs=8)
        col = col_chunk_cost(T=4, in_f=64, out_f=64, cs_out=8)
        assert col.agg_groups * 8 == row.agg_groups
        assert row.aux_rows > 0 and col.aux_rows == 4 * 64

    def test_seq_len_parameterisation(self):
        """Costs scale with T, so prefill and decode price independently."""
        r1 = row_chunk_cost(1, 64, 64, 8)
        r8 = row_chunk_cost(8, 64, 64, 8)
        p = CostParams()
        assert r8.total(p) > r1.total(p)
        assert r8.join_rows == 8 * r1.join_rows

    def test_auto_mixes_layouts_on_llama(self):
        """Cost-based planning keeps wide-input GLU_W2 row-chunked but
        rewrites o-proj / W1 / W3 / lm_head (square or wide-output)."""
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto")
        chosen = {d.table: d.layout for d in plan.decisions}
        assert chosen["o_weights_L0"] == COL_CHUNK
        assert chosen["GLU_W1_L0"] == COL_CHUNK
        assert chosen["lm_head"] == COL_CHUNK
        assert chosen["GLU_W2_L0"] == ROW_CHUNK
        for d in plan.decisions:
            want = COL_CHUNK if d.col_cost < d.row_cost else ROW_CHUNK
            assert d.layout == want

    def test_force_mode_rewrites_everything_legal(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="col")
        assert plan.decisions and all(d.layout == COL_CHUNK
                                      for d in plan.decisions)
        # weight schemas now carry the transposed tables
        assert "o_weights_L0__col" in pipe.weight_schemas
        assert "o_weights_L0" not in pipe.weight_schemas
        assert pipe.layouts["o_weights_L0__col"] == COL_CHUNK


def _run_llama_prefill(params, ids, cs, mode, cache_len=None):
    T = len(ids)
    g = build_prefill_graph(SPEC, T, cache_len=cache_len)
    infer_shapes(g)
    preoptimize(g)
    pipe = op_map(g, chunk_size=cs)
    postoptimize(pipe, layout_mode=mode)
    env = convert_weights(params, chunk_size=cs)
    env.update(empty_cache_tables(SPEC, cache_len or T, chunk_size=cs))
    env["token_ids"] = token_table(np.asarray(ids, np.int32))
    env["freq_each_token"] = rope_freq_table(np.arange(T), SPEC.head_dim,
                                             SPEC.rope_theta)
    outs, env = run_pipeline(pipe, env, scalars={"cache_position": 0})
    return (np.asarray(outs["logits"].cols["v"]).reshape(T, -1)
            [:, : SPEC.vocab], env)


@pytest.fixture(scope="module")
def params():
    return init_llama_params(SPEC, seed=0)


class TestEquivalence:
    """COL_CHUNK plans produce numerically identical outputs to ROW_CHUNK
    (acceptance: ≤1e-5 on prefill and decode for a small LlamaSpec)."""

    @pytest.mark.parametrize("mode", ["auto", "col"])
    @pytest.mark.parametrize("cs", [8, 16])
    def test_prefill_linear_attention_ffn(self, params, mode, cs):
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        row, _ = _run_llama_prefill(params, ids, cs, "off")
        col, _ = _run_llama_prefill(params, ids, cs, mode)
        np.testing.assert_allclose(col, row, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("mode", ["auto", "col"])
    def test_decode_kv_cached(self, params, mode):
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        MAXT = 9
        outs = {}
        for m in ("off", mode):
            _, env = _run_llama_prefill(params, ids, 8, m, cache_len=MAXT)
            g = build_decode_graph(SPEC, cache_len=MAXT)
            infer_shapes(g)
            preoptimize(g)
            pipe = op_map(g, chunk_size=8)
            postoptimize(pipe, layout_mode=m)
            logs, cur = [], len(ids)
            for tok in [21, 33, 7]:
                env["token_ids"] = token_table(np.asarray([tok], np.int32))
                env["freq_each_token"] = rope_freq_table(
                    np.asarray([cur]), SPEC.head_dim, SPEC.rope_theta)
                o, env = run_pipeline(pipe, env,
                                      scalars={"cache_position": cur})
                logs.append(np.asarray(o["logits"].cols["v"]).reshape(-1)
                            [: SPEC.vocab])
                cur += 1
            outs[m] = np.stack(logs)
        np.testing.assert_allclose(outs[mode], outs["off"], rtol=1e-5,
                                   atol=1e-5)

    def test_small_linear_pipeline(self):
        pipe_row, pipe_col = _linear_pipe(), _linear_pipe()
        plan = plan_layouts(pipe_col, mode="col")
        assert len(plan.col_decisions) == 1
        w, env = _linear_env()
        out_row, _ = run_pipeline(pipe_row, env.copy())
        out_col, _ = run_pipeline(pipe_col, env.copy())
        a = np.asarray(out_row["y"].cols["v"])
        b = np.asarray(out_col["y"].cols["v"])
        np.testing.assert_allclose(b, a, rtol=1e-6, atol=1e-6)
        # and both match the dense reference
        ref = w["vocab"][[3, 0, 15, 7]] @ w["W"].T
        np.testing.assert_allclose(b.reshape(4, -1), ref, rtol=1e-5,
                                   atol=1e-5)


GOLDEN_VIEW_DUCKDB = """\
CREATE OR REPLACE VIEW y AS
WITH t4 AS (SELECT S.t, S.c, E.e, S.v[E.e + 1] AS x FROM embedding_1 AS S, (SELECT UNNEST(range(4)) AS e) AS E),
  t3 AS (SELECT t AS t, ((c * 4) + e) AS d, x AS xs FROM t4),
  t2 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t3 AS L JOIN W__col AS R ON R.d = L.d)
SELECT t, c, sumForEach(LIST(list_transform(chunk, x -> x * (xs)))) AS v FROM t2 GROUP BY t, c;"""

GOLDEN_VIEW_ANSI = """\
CREATE OR REPLACE VIEW y AS
WITH t4 AS (SELECT S.t, S.c, U.ord - 1 AS e, U.x FROM embedding_1 AS S, UNNEST(S.v) WITH ORDINALITY AS U(x, ord)),
  t3 AS (SELECT t AS t, ((c * 4) + e) AS d, x AS xs FROM t4),
  t2 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t3 AS L JOIN W__col AS R ON R.d = L.d)
SELECT t, c, sumForEach(LIST(map_vec(chunk, 'x * (xs)'))) AS v FROM t2 GROUP BY t, c;"""

GOLDEN_CONVERSION_DUCKDB = """\
-- ROW2COL: W -> W__col
CREATE OR REPLACE TABLE W__col AS
WITH flat AS (SELECT j, c * 4 + e.e AS d, chunk[e.e + 1] AS x FROM W, (SELECT UNNEST(range(4)) AS e) AS e)
SELECT d, j // 4 AS c, collect_as_array(LIST(j % 4), LIST(x)) AS chunk
FROM flat GROUP BY d, j // 4;"""


class TestSQLSnapshots:
    def _sql(self, dialect):
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="col")
        return generate_sql(pipe, dialect=dialect, include_conversion=True)

    def test_conversion_omitted_by_default(self):
        """The default script is pure DDL + views: the conversion (which
        must run after data load) is opt-in."""
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="col")
        sql = generate_sql(pipe, dialect="duckdb")
        assert "CREATE OR REPLACE TABLE W__col" not in sql
        assert "CREATE TABLE W__col" in sql  # empty col DDL still present
        from repro.planner import union_conversion_sql
        conv = union_conversion_sql([pipe])
        assert "CREATE OR REPLACE TABLE W__col AS" in conv

    def test_duckdb_golden_view(self):
        sql = self._sql("duckdb")
        assert GOLDEN_VIEW_DUCKDB in sql
        assert GOLDEN_CONVERSION_DUCKDB in sql
        assert ("-- layout: col_chunk\n"
                "CREATE TABLE W__col (d INT32, c INT32, chunk FLOAT[4]);"
                in sql)

    def test_ansi_golden_view(self):
        sql = self._sql("ansi")
        assert GOLDEN_VIEW_ANSI in sql
        assert "CREATE TABLE W__col (d INT32, c INT32, chunk FLOAT[4]);" \
            in sql
        assert "WITH ORDINALITY" in sql

    def test_llama_decode_script_has_col_tables(self, params):
        g = build_decode_graph(SPEC, cache_len=16)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        postoptimize(pipe, layout_mode="col")
        for dialect in ("duckdb", "ansi"):
            sql = generate_sql(pipe, dialect=dialect)
            assert "CREATE TABLE o_weights_L0__col" in sql
            assert "JOIN o_weights_L0__col" in sql.replace("\n", " ")
            # row-chunked structures survive where COL_CHUNK is illegal
            assert "CREATE TABLE Q_weights_L0" in sql
            assert "INSERT INTO k_cache_L0" in sql


class TestEngineKnob:
    @pytest.mark.parametrize("mode", ["auto", "col"])
    def test_in_memory_matches_off(self, params, mode):
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off").generate(prompt, 4)
        got = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col=mode).generate(prompt, 4)
        assert got.tokens == ref.tokens

    def test_paged_matches_off(self, params, tmp_path):
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off").generate(prompt, 4)
        got = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="auto", residency="paged",
                               budget_bytes=1 << 20,
                               disk_dir=str(tmp_path)).generate(prompt, 4)
        assert got.tokens == ref.tokens
        assert got.pager_stats is not None
