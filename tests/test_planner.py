"""Planner subsystem tests: layout legality, cost model, ROW2COL rewrite
equivalence (executor path, prefill + decode), golden SQL snapshots for
both dialects, and the serving-engine knob."""

import numpy as np
import pytest

from repro.core.executor import (col_table_from_dense, colh_table_from_dense,
                                 execute, permute_table_keys,
                                 table_from_chunked, transpose_chunked_table,
                                 transpose_head_chunked_table)
from repro.core.chunked import ChunkedTensor
from repro.core.graph import Graph, infer_shapes
from repro.core.llama_graph import (LlamaSpec, build_decode_graph,
                                    build_prefill_graph, convert_weights,
                                    empty_cache_tables, init_llama_params,
                                    rope_freq_table, token_table)
from repro.core.opmap import op_map
from repro.core.passes import postoptimize, preoptimize
from repro.core.pipeline import run_pipeline
from repro.core.sqlgen import SQLGenerator, generate_sql
from repro.planner import (CACHE_HEAD_MAJOR, CACHE_LAYOUTS, CACHE_POS_MAJOR,
                           CACHE_ROW_CHUNK, COL_CHUNK, COL_CHUNK_HEADS,
                           ROW_CHUNK, CostParams, ResidencyPool,
                           admissible_layouts, cache_layout_cost,
                           choose_layout, col_chunk_cost, colh_chunk_cost,
                           divisor_candidates, match_cache_sites,
                           match_matmul_site, plan_layouts, row_chunk_cost,
                           site_chunk_costs)

SPEC = LlamaSpec(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=2,
                 d_ff=64, rope_theta=10000.0)


def _linear_pipe(cs=4):
    """Tiny embedding→linear pipeline (the canonical map_linear site)."""
    g = Graph(name="lin")
    g.inputs = ["ids"]
    g.annotate("ids", (("t", 4),))
    g.annotate("vocab", (("tok", 16), ("d", 8)))
    g.initializers["vocab"] = None
    g.initializers["W"] = None
    g.annotate("W", (("j", 8), ("d", 8)))
    x = g.add("embedding", ["vocab", "ids"])
    g.add("linear", [x, "W"], out_features=8, output="y")
    g.outputs = ["y"]
    infer_shapes(g)
    return op_map(g, chunk_size=cs)


def _linear_env(cs=4, seed=0):
    rng = np.random.default_rng(seed)
    w = {"vocab": rng.standard_normal((16, 8)).astype(np.float32),
         "W": rng.standard_normal((8, 8)).astype(np.float32)}
    env = convert_weights(w, chunk_size=cs)
    env["ids"] = token_table(np.asarray([3, 0, 15, 7], np.int32))
    return w, env


class TestLayoutIR:
    def test_match_linear_site(self):
        pipe = _linear_pipe()
        site = match_matmul_site("y", pipe.bindings["y"].plan)
        assert site is not None
        assert site.table == "W"
        assert site.in_features == 8 and site.out_features == 8
        assert admissible_layouts(site) == (ROW_CHUNK, COL_CHUNK)

    def test_admissibility_by_site_shape(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        sites = {s.table: s for st in pipe.steps if st.kind == "bind"
                 for s in [match_matmul_site(st.name, st.rel.plan)]
                 if s is not None}
        # two-key map_linear weights admit COL_CHUNK
        assert admissible_layouts(sites["o_weights_L0"]) == (ROW_CHUNK,
                                                            COL_CHUNK)
        assert admissible_layouts(sites["lm_head"]) == (ROW_CHUNK, COL_CHUNK)
        # per-head Q/K/V projections admit the head-blocked column layout
        q = sites["Q_weights_L0"]
        assert q.is_head_site and q.head_key == "h" and q.n_heads == 4
        assert admissible_layouts(q) == (ROW_CHUNK, COL_CHUNK_HEADS)
        k = sites["K_weights_L0"]
        assert k.head_key == "hk" and k.n_heads == 2
        assert k.col_table == "K_weights_L0__colh"
        # non-matmuls (embedding value-join, norms) never match
        assert "vocabulary" not in sites
        assert not any(t.endswith("Norm_L0") for t in sites)
        assert admissible_layouts(None) == (ROW_CHUNK,)

    def test_transpose_roundtrip(self):
        w = np.arange(32, dtype=np.float32).reshape(8, 4)
        row = table_from_chunked(ChunkedTensor.from_dense("w", w, chunk_size=2))
        col = transpose_chunked_table(row, col_chunk=4)
        assert col.keys == (("d", 4), ("c", 2))
        # col table holds Wᵀ chunked over the output dim
        np.testing.assert_array_equal(
            np.asarray(col.cols["chunk"]).reshape(4, 8), w.T)
        direct = col_table_from_dense(w, col_chunk=4)
        np.testing.assert_array_equal(np.asarray(direct.cols["chunk"]),
                                      np.asarray(col.cols["chunk"]))

    def test_head_transpose_roundtrip(self):
        """(h, r, c, chunk[cs]) -> (h, d, c', chunk[cs']) keeps the head
        block and transposes (r, d) within it."""
        w = np.arange(2 * 4 * 8, dtype=np.float32).reshape(2, 4, 8)
        row = table_from_chunked(
            ChunkedTensor.from_dense("w", w, chunk_size=4,
                                     key_names=("h", "r")))
        colh = transpose_head_chunked_table(row, col_chunk=2)
        assert colh.keys == (("h", 2), ("d", 8), ("c", 2))
        got = np.asarray(colh.cols["chunk"]).reshape(2, 8, 4)
        for h in range(2):
            np.testing.assert_array_equal(got[h], w[h].T)
        direct = colh_table_from_dense(w, col_chunk=2)
        np.testing.assert_array_equal(np.asarray(direct.cols["chunk"]),
                                      np.asarray(colh.cols["chunk"]))

    def test_permute_table_keys(self):
        """Cache re-layout is a pure name-based axis permutation."""
        from repro.core.executor import DenseTable
        from repro.core import relational as ra
        arr = np.arange(6 * 2 * 3 * 4, dtype=np.float32).reshape(6, 2, 3, 4)
        t = DenseTable(keys=(("tp", 6), ("hk", 2), ("c", 3)),
                       cols={"kv": arr}, col_types={"kv": ra.VEC(4)})
        p = permute_table_keys(t, ("hk", "tp", "c"))
        assert p.key_names == ("hk", "tp", "c")
        np.testing.assert_array_equal(np.asarray(p.cols["kv"]),
                                      arr.transpose(1, 0, 2, 3))
        back = permute_table_keys(p, t.key_names)
        np.testing.assert_array_equal(np.asarray(back.cols["kv"]), arr)


class TestCostModel:
    def test_col_avoids_reduction_key_explosion(self):
        """COL_CHUNK's GROUP BY cardinality is cs× smaller than ROW_CHUNK's
        and it pays no re-chunk tail."""
        row = row_chunk_cost(T=4, in_f=64, out_f=64, cs=8)
        col = col_chunk_cost(T=4, in_f=64, out_f=64, cs_out=8)
        assert col.agg_groups * 8 == row.agg_groups
        assert row.aux_rows > 0 and col.aux_rows == 4 * 64

    def test_seq_len_parameterisation(self):
        """Costs scale with T, so prefill and decode price independently."""
        r1 = row_chunk_cost(1, 64, 64, 8)
        r8 = row_chunk_cost(8, 64, 64, 8)
        p = CostParams()
        assert r8.total(p) > r1.total(p)
        assert r8.join_rows == 8 * r1.join_rows

    def test_head_blocked_cost_is_col_cost_over_total_out(self):
        """COL_CHUNK_HEADS prices as the column cost with m = H·dh."""
        ch = colh_chunk_cost(T=4, n_heads=4, in_f=64, head_dim=16, cs_out=8)
        c = col_chunk_cost(T=4, in_f=64, out_f=64, cs_out=8)
        assert ch.layout == COL_CHUNK_HEADS
        assert (ch.scan_rows, ch.join_rows, ch.agg_groups, ch.aux_rows) == \
            (c.scan_rows, c.join_rows, c.agg_groups, c.aux_rows)

    def test_auto_mixes_layouts_on_llama(self):
        """Cost-based planning keeps wide-input GLU_W2 row-chunked but
        rewrites o-proj / W1 / W3 / lm_head (square or wide-output) and the
        per-head projections (head-blocked)."""
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto")
        chosen = {d.table: d.layout for d in plan.decisions}
        assert chosen["o_weights_L0"] == COL_CHUNK
        assert chosen["GLU_W1_L0"] == COL_CHUNK
        assert chosen["lm_head"] == COL_CHUNK
        assert chosen["GLU_W2_L0"] == ROW_CHUNK
        assert chosen["Q_weights_L0"] == COL_CHUNK_HEADS
        for d in plan.decisions:
            col_layout = COL_CHUNK_HEADS if d.head_key else COL_CHUNK
            want = col_layout if d.col_cost < d.row_cost else ROW_CHUNK
            assert d.layout == want

    def test_force_mode_rewrites_everything_legal(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="col")
        assert plan.decisions and all(
            d.layout == (COL_CHUNK_HEADS if d.head_key else COL_CHUNK)
            for d in plan.decisions)
        # weight schemas now carry the transposed tables
        assert "o_weights_L0__col" in pipe.weight_schemas
        assert "o_weights_L0" not in pipe.weight_schemas
        assert pipe.layouts["o_weights_L0__col"] == COL_CHUNK
        assert "Q_weights_L0__colh" in pipe.weight_schemas
        assert pipe.layouts["Q_weights_L0__colh"] == COL_CHUNK_HEADS

    def test_cache_layout_costs(self):
        """head_major minimises decode read seeks; position-outer layouts
        win appends; pos_major's vectorised head-innermost reads beat
        row_chunk's per-head strides whenever n_chunks < n_heads."""
        costs = {L: cache_layout_cost(L, cache_len=512, n_heads=8,
                                      n_chunks=2) for L in CACHE_LAYOUTS}
        # scan rows are layout-invariant
        assert len({c.scan_rows for c in costs.values()}) == 1
        p = CostParams()
        assert costs[CACHE_HEAD_MAJOR].total(p) < \
            costs[CACHE_POS_MAJOR].total(p) < costs[CACHE_ROW_CHUNK].total(p)
        assert costs[CACHE_ROW_CHUNK].write_segments < \
            costs[CACHE_HEAD_MAJOR].write_segments

    def test_prefill_appends_rank_pos_major_first(self):
        """The append-dominated prefill term (ROADMAP "prefill-aware cache
        layouts", first half): when one invocation appends T ≈ S tokens,
        head_major's per-head write scatter overtakes its read advantage
        and pos_major — contiguous position-outer writes plus vectorised
        head-innermost reads — ranks first; decode pricing (T = 1) still
        ranks head_major first."""
        p = CostParams()
        S, H, C = 64, 4, 1
        prefill = {L: cache_layout_cost(L, S, H, C, new_tokens=S).total(p)
                   for L in CACHE_LAYOUTS}
        assert min(prefill, key=prefill.get) == CACHE_POS_MAJOR
        decode = {L: cache_layout_cost(L, S, H, C, new_tokens=1).total(p)
                  for L in CACHE_LAYOUTS}
        assert min(decode, key=decode.get) == CACHE_HEAD_MAJOR

    def test_batched_cache_cost_scales_with_batch(self):
        """A batched tick runs the same per-sequence locality pattern B
        times; the ranking is batch-invariant."""
        p = CostParams()
        for L in CACHE_LAYOUTS:
            c1 = cache_layout_cost(L, 128, 4, 2, new_tokens=1)
            c4 = cache_layout_cost(L, 128, 4, 2, new_tokens=1, batch=4)
            assert c4.total(p) == 4 * c1.total(p)

    def test_batched_site_prices_one_token_per_seq(self):
        """Regression: a *batched* cache site appends one token per
        sequence per tick even under a large ``params.seq_len`` — the
        seq key, not the batch size, is the discriminator (a B=1 batched
        plan must not be priced as a prefill-style bulk append)."""
        from repro.core.graph import infer_shapes
        from repro.planner.cost import cache_site_costs
        g = build_decode_graph(SPEC, cache_len=64, batch=1)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        sites = match_cache_sites(pipe)
        assert sites and all(s.seq_key == "seq" and s.batch == 1
                             for s in sites)
        costs = cache_site_costs(sites[0], CostParams(seq_len=512))
        # decode-dominated pricing: head_major first, not the
        # append-dominated pos_major ranking
        assert min(costs, key=costs.get) == CACHE_HEAD_MAJOR


def _run_llama_prefill(params, ids, cs, mode, cache_len=None):
    T = len(ids)
    g = build_prefill_graph(SPEC, T, cache_len=cache_len)
    infer_shapes(g)
    preoptimize(g)
    pipe = op_map(g, chunk_size=cs)
    postoptimize(pipe, layout_mode=mode)
    env = convert_weights(params, chunk_size=cs)
    env.update(empty_cache_tables(SPEC, cache_len or T, chunk_size=cs))
    env["token_ids"] = token_table(np.asarray(ids, np.int32))
    env["freq_each_token"] = rope_freq_table(np.arange(T), SPEC.head_dim,
                                             SPEC.rope_theta)
    outs, env = run_pipeline(pipe, env, scalars={"cache_position": 0})
    return (np.asarray(outs["logits"].cols["v"]).reshape(T, -1)
            [:, : SPEC.vocab], env)


@pytest.fixture(scope="module")
def params():
    return init_llama_params(SPEC, seed=0)


class TestEquivalence:
    """COL_CHUNK plans produce numerically identical outputs to ROW_CHUNK
    (acceptance: ≤1e-5 on prefill and decode for a small LlamaSpec)."""

    @pytest.mark.parametrize("mode", ["auto", "col"])
    @pytest.mark.parametrize("cs", [8, 16])
    def test_prefill_linear_attention_ffn(self, params, mode, cs):
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        row, _ = _run_llama_prefill(params, ids, cs, "off")
        col, _ = _run_llama_prefill(params, ids, cs, mode)
        np.testing.assert_allclose(col, row, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("mode", ["auto", "col"])
    def test_decode_kv_cached(self, params, mode):
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        MAXT = 9
        outs = {}
        for m in ("off", mode):
            _, env = _run_llama_prefill(params, ids, 8, m, cache_len=MAXT)
            g = build_decode_graph(SPEC, cache_len=MAXT)
            infer_shapes(g)
            preoptimize(g)
            pipe = op_map(g, chunk_size=8)
            postoptimize(pipe, layout_mode=m)
            logs, cur = [], len(ids)
            for tok in [21, 33, 7]:
                env["token_ids"] = token_table(np.asarray([tok], np.int32))
                env["freq_each_token"] = rope_freq_table(
                    np.asarray([cur]), SPEC.head_dim, SPEC.rope_theta)
                o, env = run_pipeline(pipe, env,
                                      scalars={"cache_position": cur})
                logs.append(np.asarray(o["logits"].cols["v"]).reshape(-1)
                            [: SPEC.vocab])
                cur += 1
            outs[m] = np.stack(logs)
        np.testing.assert_allclose(outs[mode], outs["off"], rtol=1e-5,
                                   atol=1e-5)

    def test_small_linear_pipeline(self):
        pipe_row, pipe_col = _linear_pipe(), _linear_pipe()
        plan = plan_layouts(pipe_col, mode="col")
        assert len(plan.col_decisions) == 1
        w, env = _linear_env()
        out_row, _ = run_pipeline(pipe_row, env.copy())
        out_col, _ = run_pipeline(pipe_col, env.copy())
        a = np.asarray(out_row["y"].cols["v"])
        b = np.asarray(out_col["y"].cols["v"])
        np.testing.assert_allclose(b, a, rtol=1e-6, atol=1e-6)
        # and both match the dense reference
        ref = w["vocab"][[3, 0, 15, 7]] @ w["W"].T
        np.testing.assert_allclose(b.reshape(4, -1), ref, rtol=1e-5,
                                   atol=1e-5)

    def test_linear_heads_site_rewritten_matches_row(self, params):
        """A map_linear_heads site rewritten to COL_CHUNK_HEADS produces
        the same Q projection as the ROW_CHUNK reference (acceptance)."""
        outs = {}
        for mode in ("off", "col"):
            g = build_prefill_graph(SPEC, 4)
            infer_shapes(g)
            preoptimize(g)
            pipe = op_map(g, chunk_size=8)
            postoptimize(pipe, layout_mode=mode)
            if mode == "col":
                heads = [d for d in pipe.layout_plan.col_decisions
                         if d.head_key]
                assert {d.layout for d in heads} == {COL_CHUNK_HEADS}
            env = convert_weights(params, chunk_size=8)
            env.update(empty_cache_tables(SPEC, 4, chunk_size=8))
            env["token_ids"] = token_table(np.asarray([3, 0, 5, 7], np.int32))
            env["freq_each_token"] = rope_freq_table(
                np.arange(4), SPEC.head_dim, SPEC.rope_theta)
            # linear_heads_1 is the first Q projection bind
            q_step = next(s.name for s in pipe.steps
                          if s.kind == "bind"
                          and s.name.startswith("linear_heads"))
            pipe.outputs = [q_step]
            o, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
            outs[mode] = np.asarray(o[q_step].cols["v"])
        assert outs["col"].shape == outs["off"].shape  # (t, h, c, cs)
        np.testing.assert_allclose(outs["col"], outs["off"], rtol=1e-5,
                                   atol=1e-5)


class TestCacheLayouts:
    """Planner-managed KV-cache key orders: matching, rewrite, and decode
    equivalence against the seed row-chunk reference."""

    def test_match_cache_sites(self):
        g = build_decode_graph(SPEC, cache_len=8)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        sites = {s.table: s for s in match_cache_sites(pipe)}
        assert set(sites) == {f"{p}_cache_L{L}" for p in "kv"
                              for L in range(SPEC.n_layers)}
        s = sites["k_cache_L0"]
        assert (s.pos_key, s.head_key, s.chunk_key) == ("tp", "hk", "c")
        assert s.n_pos == 8 and s.n_heads == SPEC.n_kv

    def test_auto_picks_head_major_for_decode(self):
        g = build_decode_graph(SPEC, cache_len=64)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="off", cache_mode="auto")
        assert plan.cache_decisions
        assert all(d.layout == CACHE_HEAD_MAJOR
                   for d in plan.cache_decisions)
        # the rewrite re-keys the scans and the input schemas
        assert pipe.input_schemas["k_cache_L0"].key_names == ("hk", "tp",
                                                              "c")
        assert pipe.layouts["k_cache_L0"] == CACHE_HEAD_MAJOR

    @pytest.mark.parametrize("layout", [CACHE_HEAD_MAJOR, CACHE_POS_MAJOR])
    def test_decode_against_relaid_cache_matches_row(self, params, layout):
        """A decode step against a re-laid-out KV cache is numerically
        identical to the seed row-chunk reference (acceptance)."""
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        MAXT = 9
        outs = {}
        for cmode in (CACHE_ROW_CHUNK, layout):
            pre = _build_pipe("prefill", len(ids), 8, "off", MAXT,
                              cache_mode=cmode)
            dec = _build_pipe("decode", 1, 8, "off", MAXT, cache_mode=cmode)
            env = convert_weights(params, chunk_size=8)
            env.update(empty_cache_tables(SPEC, MAXT, chunk_size=8,
                                          layout=cmode))
            env["token_ids"] = token_table(ids)
            env["freq_each_token"] = rope_freq_table(
                np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
            _, env = run_pipeline(pre, env, scalars={"cache_position": 0})
            logs, cur = [], len(ids)
            for tok in [21, 33, 7]:
                env["token_ids"] = token_table(np.asarray([tok], np.int32))
                env["freq_each_token"] = rope_freq_table(
                    np.asarray([cur]), SPEC.head_dim, SPEC.rope_theta)
                o, env = run_pipeline(dec, env,
                                      scalars={"cache_position": cur})
                logs.append(np.asarray(o["logits"].cols["v"]).reshape(-1)
                            [: SPEC.vocab])
                cur += 1
            outs[cmode] = np.stack(logs)
        np.testing.assert_allclose(outs[layout], outs[CACHE_ROW_CHUNK],
                                   rtol=1e-5, atol=1e-5)

    def test_ensure_env_aligns_seed_cache(self, params):
        """An env built with seed-order caches is permuted on first use."""
        dec = _build_pipe("decode", 1, 8, "off", 8,
                          cache_mode=CACHE_HEAD_MAJOR)
        env = convert_weights(params, chunk_size=8)
        env.update(empty_cache_tables(SPEC, 8, chunk_size=8))  # seed order
        env["token_ids"] = token_table(np.asarray([1], np.int32))
        env["freq_each_token"] = rope_freq_table(
            np.asarray([0]), SPEC.head_dim, SPEC.rope_theta)
        o, env2 = run_pipeline(dec, env, scalars={"cache_position": 0})
        assert env2["k_cache_L0"].key_names == ("hk", "tp", "c")


def _build_pipe(kind, T, cs, mode, cache_len, cache_mode="off"):
    g = (build_prefill_graph(SPEC, T, cache_len=cache_len)
         if kind == "prefill" else build_decode_graph(SPEC, cache_len))
    infer_shapes(g)
    preoptimize(g)
    pipe = op_map(g, chunk_size=cs)
    postoptimize(pipe, layout_mode=mode, cache_mode=cache_mode)
    return pipe


class TestResidencyBudget:
    """The global residency pass never exceeds the configured budget and
    degrades per-layer instead of all-or-nothing."""

    def _plan(self, budget):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        return plan_layouts(pipe, mode="auto", budget_bytes=budget)

    def test_budget_sweep_never_exceeded(self):
        unbounded = self._plan(None)
        want_bytes = sum(d.weight_bytes for d in unbounded.col_decisions)
        assert want_bytes > 0
        for budget in [0, want_bytes // 8, want_bytes // 4,
                       want_bytes // 2, want_bytes - 1, want_bytes,
                       2 * want_bytes]:
            plan = self._plan(budget)
            spent = sum(d.weight_bytes for d in plan.col_decisions)
            assert spent == plan.residency_bytes
            assert spent <= budget, (spent, budget)
            # partial budgets admit a strict subset, not all-or-nothing
            if 0 < budget < want_bytes:
                assert 0 < len(plan.col_decisions) < \
                    len(unbounded.col_decisions)
            # denied sites are flagged and stay row-chunk
            for d in plan.decisions:
                if d.denied_by_budget:
                    assert d.layout == ROW_CHUNK

    def test_zero_budget_degrades_to_row(self):
        plan = self._plan(0)
        assert plan.col_decisions == []
        assert all(d.layout == ROW_CHUNK for d in plan.decisions)
        assert any(d.denied_by_budget for d in plan.decisions)

    def test_partial_budget_keeps_best_benefit_per_byte(self):
        unbounded = self._plan(None)
        ranked = sorted(unbounded.col_decisions,
                        key=lambda d: (d.row_cost - d.col_cost)
                        / max(d.weight_bytes, 1), reverse=True)
        budget = ranked[0].weight_bytes
        plan = self._plan(budget)
        kept = {d.table for d in plan.col_decisions}
        assert ranked[0].table in kept

    def test_budgeted_plan_still_equivalent(self, params):
        """A partially-degraded plan stays numerically correct."""
        ids = np.array([3, 17, 42, 5], np.int32)
        row, _ = _run_llama_prefill(params, ids, 8, "off")
        g = build_prefill_graph(SPEC, len(ids))
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=8)
        postoptimize(pipe, layout_mode="off")
        plan = plan_layouts(pipe, mode="auto", budget_bytes=1 << 14)
        assert plan.col_decisions and any(d.denied_by_budget
                                          for d in plan.decisions)
        env = convert_weights(params, chunk_size=8)
        env.update(empty_cache_tables(SPEC, len(ids), chunk_size=8))
        env["token_ids"] = token_table(ids)
        env["freq_each_token"] = rope_freq_table(
            np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        got = np.asarray(outs["logits"].cols["v"]).reshape(len(ids), -1)[
            :, : SPEC.vocab]
        np.testing.assert_allclose(got, row, rtol=1e-5, atol=1e-5)


CHUNK_CANDS = (4, 8, 16, 32)


class TestChunkPlanning:
    """chunk_mode="auto": per-table (layout, chunk_size) pairs are planned
    jointly, rewritten with re-chunk adapters, and stay numerically exact."""

    def test_site_chunk_costs_candidate_sets(self):
        pipe = _linear_pipe()
        site = match_matmul_site("y", pipe.bindings["y"].plan)
        row_costs, col_costs = site_chunk_costs(site, CostParams(seq_len=4),
                                                (2, 4, 8, 16))
        # in/out dims are 8: candidates are divisors plus the seed size
        assert set(row_costs) == {2, 4, 8}
        assert set(col_costs) == {2, 4, 8}
        # the seed sizes carry no adapter; others do
        assert row_costs[site.row_chunk].rechunk_rows == 0
        assert col_costs[site.col_chunk].rechunk_rows == 0
        assert row_costs[8].rechunk_rows > 0
        assert col_costs[8].rechunk_rows > 0

    def test_divisor_candidates_padding_free(self):
        assert divisor_candidates(64, (4, 8, 48, 128)) == (4, 8)
        assert divisor_candidates(64, (), always=(16,)) == (16,)

    def test_joint_selection_records_pairs(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto", chunk_mode="auto",
                            chunk_candidates=CHUNK_CANDS)
        assert plan.decisions
        for d in plan.decisions:
            assert d.chunk_size in CHUNK_CANDS + (d.row_chunk, d.col_chunk)
            dim = d.in_features if d.layout == ROW_CHUNK else d.out_features
            assert dim % d.chunk_size == 0  # pad-free physical tables
        # the planner actually uses the freedom (seed chunk is 8)
        assert any(d.chunk_size != 8 for d in plan.decisions)
        # chosen sizes are recorded for sqlgen/engine threading
        assert pipe.table_chunks
        for t, cs in pipe.table_chunks.items():
            assert t in pipe.weight_schemas
            from repro.core import relational as ra
            assert ra.vec_width(pipe.weight_schemas[t].cols[0][1]) == cs

    def test_chunk_mode_off_reproduces_seed_plans(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto")  # chunk_mode defaults off
        assert all(d.chunk_size in (d.row_chunk, d.col_chunk)
                   for d in plan.decisions)
        assert pipe.table_chunks == {}

    def test_chunk_auto_requires_layout_planner(self):
        pipe = _linear_pipe()
        with pytest.raises(ValueError):
            plan_layouts(pipe, mode="off", chunk_mode="auto")

    def test_forced_table_chunks_pin_sizes(self):
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        forced = {"GLU_W2_L0": 16, "GLU_W2_L1": 16}
        plan = plan_layouts(pipe, mode="auto", chunk_mode="auto",
                            chunk_candidates=CHUNK_CANDS,
                            table_chunks=forced)
        by_table = {d.table: d for d in plan.decisions}
        for t, cs in forced.items():
            if by_table[t].layout == ROW_CHUNK:
                assert by_table[t].chunk_size == cs

    def test_forced_chunk_outside_candidate_grid_is_priced(self):
        """A forced size need not sit in the candidate grid — any divisor
        of the chunked dimension is priced directly (regression: it used
        to be rejected as inadmissible)."""
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto", chunk_mode="auto",
                            chunk_candidates=(8, 32),  # 16 not in the grid
                            table_chunks={"GLU_W2_L0": 16},
                            budget_bytes=0)  # deny col: ROW must honour it
        d = next(d for d in plan.decisions if d.table == "GLU_W2_L0")
        assert d.layout == ROW_CHUNK and d.chunk_size == 16
        # a non-divisor forced size is still an error (with the real reason)
        g2 = build_prefill_graph(SPEC, 4)
        infer_shapes(g2)
        pipe2 = op_map(g2, chunk_size=8)
        with pytest.raises(ValueError, match="does not divide"):
            plan_layouts(pipe2, mode="auto", chunk_mode="auto",
                         chunk_candidates=(8, 32),
                         table_chunks={"GLU_W2_L0": 48})

    def test_prefill_equivalence_chunk_auto(self, params):
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        base, _ = _run_llama_prefill(params, ids, 8, "off")
        g = build_prefill_graph(SPEC, len(ids))
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=8)
        postoptimize(pipe, layout_mode="auto", chunk_mode="auto",
                     chunk_candidates=CHUNK_CANDS)
        env = convert_weights(params, chunk_size=8)
        env.update(empty_cache_tables(SPEC, len(ids), chunk_size=8))
        env["token_ids"] = token_table(ids)
        env["freq_each_token"] = rope_freq_table(
            np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
        outs, _ = run_pipeline(pipe, env, scalars={"cache_position": 0})
        got = np.asarray(outs["logits"].cols["v"]).reshape(len(ids), -1)[
            :, : SPEC.vocab]
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)

    def test_decode_equivalence_chunk_auto(self, params):
        """End-to-end KV-cached decode under per-table chunk planning is
        numerically identical to the fixed-chunk baseline (acceptance)."""
        ids = np.array([3, 17, 42, 5, 9], np.int32)
        MAXT = 9
        outs = {}
        for chunk_mode in ("off", "auto"):
            pre = _build_pipe("prefill", len(ids), 8, "off", MAXT)
            g = build_decode_graph(SPEC, cache_len=MAXT)
            infer_shapes(g)
            preoptimize(g)
            dec = op_map(g, chunk_size=8)
            postoptimize(dec, layout_mode=("off" if chunk_mode == "off"
                                           else "auto"),
                         chunk_mode=chunk_mode,
                         chunk_candidates=CHUNK_CANDS)
            env = convert_weights(params, chunk_size=8)
            env.update(empty_cache_tables(SPEC, MAXT, chunk_size=8))
            env["token_ids"] = token_table(ids)
            env["freq_each_token"] = rope_freq_table(
                np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
            _, env = run_pipeline(pre, env, scalars={"cache_position": 0})
            logs, cur = [], len(ids)
            for tok in [21, 33, 7]:
                env["token_ids"] = token_table(np.asarray([tok], np.int32))
                env["freq_each_token"] = rope_freq_table(
                    np.asarray([cur]), SPEC.head_dim, SPEC.rope_theta)
                o, env = run_pipeline(dec, env,
                                      scalars={"cache_position": cur})
                logs.append(np.asarray(o["logits"].cols["v"]).reshape(-1)
                            [: SPEC.vocab])
                cur += 1
            outs[chunk_mode] = np.stack(logs)
        np.testing.assert_allclose(outs["auto"], outs["off"], rtol=1e-5,
                                   atol=1e-5)

    def test_zero_budget_rechunks_row_tables(self, params):
        """With every column copy denied, chunk planning still re-chunks
        the row tables in place (no duplicate bytes) and stays exact."""
        ids = np.array([3, 17, 42, 5], np.int32)
        base, _ = _run_llama_prefill(params, ids, 8, "off")
        g = build_prefill_graph(SPEC, len(ids))
        infer_shapes(g)
        preoptimize(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="auto", chunk_mode="auto",
                            chunk_candidates=CHUNK_CANDS, budget_bytes=0)
        assert plan.col_decisions == []
        rechunked = [d for d in plan.decisions
                     if d.layout == ROW_CHUNK and d.chunk_size != d.row_chunk]
        assert rechunked, "expected in-place row re-chunk decisions"
        env = convert_weights(params, chunk_size=8)
        env.update(empty_cache_tables(SPEC, len(ids), chunk_size=8))
        env["token_ids"] = token_table(ids)
        env["freq_each_token"] = rope_freq_table(
            np.arange(len(ids)), SPEC.head_dim, SPEC.rope_theta)
        outs, env2 = run_pipeline(pipe, env, scalars={"cache_position": 0})
        got = np.asarray(outs["logits"].cols["v"]).reshape(len(ids), -1)[
            :, : SPEC.vocab]
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)
        # the environment's row table really was re-chunked
        d = rechunked[0]
        from repro.core import relational as ra
        vec_col = next(iter(env2[d.table].cols))
        assert ra.vec_width(env2[d.table].col_types[vec_col]) == d.chunk_size

    def test_rechunk_helper_roundtrip(self):
        from repro.core.executor import rechunk_chunked_table
        w = np.arange(6 * 12, dtype=np.float32).reshape(6, 12)
        t = table_from_chunked(ChunkedTensor.from_dense("w", w, chunk_size=4))
        r = rechunk_chunked_table(t, 6)
        assert r.keys == (("row_id", 6), ("chunk_id", 2))
        np.testing.assert_array_equal(
            np.asarray(r.cols["chunk"]).reshape(6, 12), w)
        # non-divisor target pads with zeros
        r2 = rechunk_chunked_table(t, 5)
        assert r2.keys[-1] == ("chunk_id", 3)
        flat = np.asarray(r2.cols["chunk"]).reshape(6, 15)
        np.testing.assert_array_equal(flat[:, :12], w)
        np.testing.assert_array_equal(flat[:, 12:], 0)


GOLDEN_VIEW_DUCKDB = """\
CREATE OR REPLACE VIEW y AS
WITH t4 AS (SELECT S.t, S.c, E.e, S.v[E.e + 1] AS x FROM embedding_1 AS S, (SELECT UNNEST(range(4)) AS e) AS E),
  t3 AS (SELECT t AS t, ((c * 4) + e) AS d, x AS xs FROM t4),
  t2 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t3 AS L JOIN W__col AS R ON R.d = L.d)
SELECT t, c, sumForEach(LIST(list_transform(chunk, x -> x * (xs)))) AS v FROM t2 GROUP BY t, c;"""

GOLDEN_VIEW_ANSI = """\
CREATE OR REPLACE VIEW y AS
WITH t4 AS (SELECT S.t, S.c, U.ord - 1 AS e, U.x FROM embedding_1 AS S, UNNEST(S.v) WITH ORDINALITY AS U(x, ord)),
  t3 AS (SELECT t AS t, ((c * 4) + e) AS d, x AS xs FROM t4),
  t2 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t3 AS L JOIN W__col AS R ON R.d = L.d)
SELECT t, c, sumForEach(LIST(map_vec(chunk, 'x * (xs)'))) AS v FROM t2 GROUP BY t, c;"""

GOLDEN_CONVERSION_DUCKDB = """\
-- ROW2COL: W -> W__col
CREATE OR REPLACE TABLE W__col AS
WITH flat AS (SELECT j, c * 4 + e.e AS d, chunk[e.e + 1] AS x FROM W, (SELECT UNNEST(range(4)) AS e) AS e)
SELECT d, j // 4 AS c, collect_as_array(LIST(j % 4), LIST(x)) AS chunk
FROM flat GROUP BY d, j // 4;"""


class TestSQLSnapshots:
    def _sql(self, dialect):
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="col")
        return generate_sql(pipe, dialect=dialect, include_conversion=True)

    def test_conversion_omitted_by_default(self):
        """The default script is pure DDL + views: the conversion (which
        must run after data load) is opt-in."""
        pipe = _linear_pipe()
        plan_layouts(pipe, mode="col")
        sql = generate_sql(pipe, dialect="duckdb")
        assert "CREATE OR REPLACE TABLE W__col" not in sql
        assert "CREATE TABLE W__col" in sql  # empty col DDL still present
        from repro.planner import union_conversion_sql
        conv = union_conversion_sql([pipe])
        assert "CREATE OR REPLACE TABLE W__col AS" in conv

    def test_duckdb_golden_view(self):
        sql = self._sql("duckdb")
        assert GOLDEN_VIEW_DUCKDB in sql
        assert GOLDEN_CONVERSION_DUCKDB in sql
        assert ("-- layout: col_chunk\n"
                "CREATE TABLE W__col (d INT32, c INT32, chunk FLOAT[4]);"
                in sql)

    def test_ansi_golden_view(self):
        sql = self._sql("ansi")
        assert GOLDEN_VIEW_ANSI in sql
        assert "CREATE TABLE W__col (d INT32, c INT32, chunk FLOAT[4]);" \
            in sql
        assert "WITH ORDINALITY" in sql

    def test_llama_decode_script_has_col_tables(self, params):
        g = build_decode_graph(SPEC, cache_len=16)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        postoptimize(pipe, layout_mode="col")
        for dialect in ("duckdb", "ansi"):
            sql = generate_sql(pipe, dialect=dialect)
            assert "CREATE TABLE o_weights_L0__col" in sql
            assert "JOIN o_weights_L0__col" in sql.replace("\n", " ")
            # per-head projections now transpose to head-blocked col tables
            assert "CREATE TABLE Q_weights_L0__colh" in sql
            assert "JOIN Q_weights_L0__colh" in sql.replace("\n", " ")
            # the ROW_CHUNK sources survive as conversion inputs
            assert "CREATE TABLE Q_weights_L0 " in sql
            assert "INSERT INTO k_cache_L0" in sql

    def test_head_conversion_sql_carries_head_key(self):
        g = build_decode_graph(SPEC, cache_len=16)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan = plan_layouts(pipe, mode="col")
        conv = plan.conversion_sql("duckdb")
        assert ("-- ROW2COL (head-blocked): Q_weights_L0 -> "
                "Q_weights_L0__colh") in conv
        assert "GROUP BY h, d, r // 8" in conv
        # K/V use their own head key name
        assert "GROUP BY hk, d, r // 8" in conv

    def test_cache_ddl_annotated_with_layout(self):
        g = build_decode_graph(SPEC, cache_len=16)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        postoptimize(pipe, layout_mode="off", cache_mode="head_major")
        sql = generate_sql(pipe, dialect="duckdb")
        assert ("-- layout: head_major\n"
                "CREATE TABLE k_cache_L0 (hk INT32, tp INT32, c INT32, "
                "kv FLOAT[8]);") in sql
        # the INSERT names its columns so the SELECT's (tp, hk, c) order
        # binds correctly against the permuted DDL
        assert "INSERT INTO k_cache_L0 (tp, hk, c, kv)" in sql


class TestEngineKnob:
    @pytest.mark.parametrize("mode", ["auto", "col"])
    def test_in_memory_matches_off(self, params, mode):
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off").generate(prompt, 4)
        got = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col=mode).generate(prompt, 4)
        assert got.tokens == ref.tokens

    @pytest.mark.parametrize("cache_layout", ["head_major", "pos_major",
                                              "auto"])
    def test_cache_layout_knob_matches_off(self, params, cache_layout):
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off",
                               cache_layout="off").generate(prompt, 4)
        eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="auto", cache_layout=cache_layout)
        if cache_layout != "auto":
            assert eng.cache_layout == cache_layout
        got = eng.generate(prompt, 4)
        assert got.tokens == ref.tokens

    def test_cache_layout_defaults_to_auto(self, params):
        """ISSUE 5 satellite: the engine default flipped from "off" to
        "auto" — the decode plan resolves it to the locality model's
        choice (head_major: the layout the fresh BENCH_attn_layout sweep
        measures fastest at the largest cache length) and generation
        stays identical to the seed key order."""
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=16)
        assert eng.cache_layout == CACHE_HEAD_MAJOR
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               cache_layout="off")
        assert eng.generate(prompt, 4).tokens == ref.generate(prompt,
                                                              4).tokens

    def test_paged_matches_off(self, params, tmp_path):
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off").generate(prompt, 4)
        got = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="auto", residency="paged",
                               budget_bytes=1 << 20,
                               disk_dir=str(tmp_path)).generate(prompt, 4)
        assert got.tokens == ref.tokens
        assert got.pager_stats is not None

    def test_chunk_auto_matches_fixed_baseline(self, params):
        """chunk_size="auto": the planner picks the base and per-table
        chunk sizes; generation is identical to the fixed-chunk engine
        (acceptance: jax-executor end-to-end equivalence)."""
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off").generate(prompt, 4)
        eng = RelationalEngine(SPEC, params, chunk_size="auto", max_len=16,
                               chunk_candidates=(4, 8, 16, 32))
        assert eng.cs in (4, 8, 16, 32)
        assert eng._table_chunks  # per-table choices were planned
        got = eng.generate(prompt, 4)
        assert got.tokens == ref.tokens

    def test_chunk_auto_paged_matches_fixed_baseline(self, params,
                                                     tmp_path):
        from repro.serving.engine import RelationalEngine
        prompt = [3, 17, 42, 5, 9]
        ref = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               row2col="off").generate(prompt, 4)
        got = RelationalEngine(SPEC, params, chunk_size="auto", max_len=16,
                               chunk_candidates=(4, 8, 16, 32),
                               residency="paged", budget_bytes=1 << 20,
                               disk_dir=str(tmp_path)).generate(prompt, 4)
        assert got.tokens == ref.tokens

    def test_chunk_auto_paged_planned_sizes_differ_from_base(self,
                                                             tmp_path):
        """Regression: paged sessions must wrap cold weights at the
        *planner's* per-table chunk sizes, not the base size — a spec
        whose planned sizes genuinely differ from min(base, width) used
        to crash in generate() with a schema/size mismatch."""
        from repro.serving.engine import RelationalEngine
        spec = LlamaSpec(vocab=64, d_model=32, n_layers=2, n_heads=4,
                         n_kv=2, d_ff=48, rope_theta=10000.0)
        p48 = init_llama_params(spec, seed=0)
        prompt = [3, 17, 42]
        eng = RelationalEngine(spec, p48, chunk_size="auto", max_len=16,
                               chunk_candidates=(16, 48),
                               residency="paged", budget_bytes=1 << 20,
                               disk_dir=str(tmp_path))
        mismatched = {t: cs for t, cs in eng._table_chunks.items()
                      if cs != eng.cs}
        assert mismatched  # the regression's trigger condition holds
        ref = RelationalEngine(spec, p48, chunk_size=eng.cs, max_len=16,
                               row2col="off").generate(prompt, 4)
        assert eng.generate(prompt, 4).tokens == ref.tokens

    def test_chunk_auto_rejects_row2col_off(self, params):
        from repro.serving.engine import RelationalEngine
        with pytest.raises(ValueError):
            RelationalEngine(SPEC, params, chunk_size="auto", max_len=16,
                             row2col="off")


class TestSharedResidencyPool:
    """Prefill and decode plans draw on ONE residency budget pool (ROADMAP
    "residency budget across pipelines") instead of each receiving the
    full cap."""

    def _plan_into(self, pool, kind, T=4):
        g = (build_prefill_graph(SPEC, T) if kind == "prefill"
             else build_decode_graph(SPEC, cache_len=8))
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        return plan_layouts(pipe, mode="auto", pool=pool)

    def test_budget_split_across_pipelines(self):
        # how much an unbounded decode plan wants
        want = sum(d.weight_bytes for d in
                   self._plan_into(ResidencyPool(None),
                                   "decode").col_decisions)
        assert want > 0
        # a budget that fits exactly the decode plan: the prefill plan must
        # NOT get a second copy of it — shared tables are free, new ones
        # are denied
        pool = ResidencyPool(want)
        dplan = self._plan_into(pool, "decode")
        assert pool.spent == want
        pplan = self._plan_into(pool, "prefill")
        assert pool.spent <= want  # no budget doubling
        committed = set(pool.tables)
        for d in pplan.col_decisions:
            assert d.col_table in committed
        # the prefill plan added no *new* residency bytes
        assert pplan.residency_bytes == 0 or \
            pool.spent - want == pplan.residency_bytes

    def test_shared_tables_counted_once(self):
        pool = ResidencyPool(None)
        p1 = self._plan_into(pool, "decode")
        spent_after_first = pool.spent
        p2 = self._plan_into(pool, "decode", T=4)
        # identical table set: the second plan commits nothing new
        assert pool.spent == spent_after_first
        assert p2.residency_bytes == 0
        assert {d.col_table for d in p2.col_decisions} <= set(pool.tables)

    def test_pool_pins_chunk_sizes_across_plans(self):
        """Two chunk-planned pipelines over one pool may never declare
        different physical widths for a shared table — the pool pins each
        committed table's chunk size for later plans."""
        from repro.core import relational as ra
        pool = ResidencyPool(None)

        def plan(kind, T=4):
            g = (build_prefill_graph(SPEC, T) if kind == "prefill"
                 else build_decode_graph(SPEC, cache_len=8))
            infer_shapes(g)
            pipe = op_map(g, chunk_size=8)
            plan_layouts(pipe, mode="auto", chunk_mode="auto",
                         chunk_candidates=(4, 8, 16, 32), pool=pool)
            return pipe

        dec = plan("decode")
        pre = plan("prefill")  # no explicit table_chunks pinning
        dw = {t: ra.vec_width(s.cols[0][1])
              for t, s in dec.weight_schemas.items()}
        pw = {t: ra.vec_width(s.cols[0][1])
              for t, s in pre.weight_schemas.items()}
        for t in set(dw) & set(pw):
            assert dw[t] == pw[t], t

    def test_engine_shares_one_pool(self, params, tmp_path):
        """The engine's decode + prefill plans never commit more than the
        configured budget in total, and prefill reuses decode's copies."""
        from repro.serving.engine import RelationalEngine
        budget = 1 << 20
        eng = RelationalEngine(SPEC, params, chunk_size=8, max_len=16,
                               residency="paged", budget_bytes=budget,
                               disk_dir=str(tmp_path))
        eng.generate([3, 17, 42, 5, 9], 3)  # builds a prefill pipe
        pool = eng._residency_pool
        assert pool.budget_bytes == budget
        assert pool.spent <= budget
        assert pool.spent == sum(pool.tables.values())
        prefill_pipe = next(iter(eng._prefill_pipes.values()))
        for d in prefill_pipe.layout_plan.col_decisions:
            assert d.col_table in pool.tables


GOLDEN_CHUNK_DDL_DUCKDB = """\
-- layout: col_chunk; chunk_size: 8 (planner)
CREATE TABLE W__col (d INT32, c INT32, chunk FLOAT[8]);"""

GOLDEN_CHUNK_CONVERSION_DUCKDB = """\
-- ROW2COL: W -> W__col
CREATE OR REPLACE TABLE W__col AS
WITH flat AS (SELECT j, c * 2 + e.e AS d, chunk[e.e + 1] AS x FROM W, (SELECT UNNEST(range(2)) AS e) AS e)
SELECT d, j // 8 AS c, collect_as_array(LIST(j % 8), LIST(x)) AS chunk
FROM flat GROUP BY d, j // 8;"""

GOLDEN_CHUNK_VIEW_DUCKDB = """\
CREATE OR REPLACE VIEW y AS
WITH t8 AS (SELECT S.t, S.c, E.e, S.v[E.e + 1] AS x FROM embedding_1 AS S, (SELECT UNNEST(range(2)) AS e) AS E),
  t7 AS (SELECT t AS t, ((c * 2) + e) AS d, x AS xs FROM t8),
  t6 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t7 AS L JOIN W__col AS R ON R.d = L.d),
  t5 AS (SELECT t, c, sumForEach(LIST(list_transform(chunk, x -> x * (xs)))) AS v FROM t6 GROUP BY t, c),
  t4 AS (SELECT S.t, S.c, E.e, S.v[E.e + 1] AS x FROM t5 AS S, (SELECT UNNEST(range(8)) AS e) AS E),
  t3 AS (SELECT t AS t, ((c * 8) + e) AS r, x AS x FROM t4),
  t2 AS (SELECT t AS t, (r // 2) AS c, (r % 2) AS e, x AS x FROM t3)
SELECT t, c, collect_as_array(LIST(e), LIST(x)) AS v FROM t2 GROUP BY t, c;"""

GOLDEN_CHUNK_CONVERSION_ANSI = """\
-- ROW2COL: W -> W__col
CREATE OR REPLACE TABLE W__col AS
WITH flat AS (SELECT j, c * 2 + u.ord - 1 AS d, u.x AS x FROM W, UNNEST(chunk) WITH ORDINALITY AS u(x, ord))
SELECT d, j / 8 AS c, collect_as_array(LIST(j % 8), LIST(x)) AS chunk
FROM flat GROUP BY d, j / 8;"""

GOLDEN_CHUNK_VIEW_ANSI = """\
CREATE OR REPLACE VIEW y AS
WITH t8 AS (SELECT S.t, S.c, U.ord - 1 AS e, U.x FROM embedding_1 AS S, UNNEST(S.v) WITH ORDINALITY AS U(x, ord)),
  t7 AS (SELECT t AS t, ((c * 2) + e) AS d, x AS xs FROM t8),
  t6 AS (SELECT L.t, L.d, R.c, L.xs, R.chunk AS chunk FROM t7 AS L JOIN W__col AS R ON R.d = L.d),
  t5 AS (SELECT t, c, sumForEach(LIST(map_vec(chunk, 'x * (xs)'))) AS v FROM t6 GROUP BY t, c),
  t4 AS (SELECT S.t, S.c, U.ord - 1 AS e, U.x FROM t5 AS S, UNNEST(S.v) WITH ORDINALITY AS U(x, ord)),
  t3 AS (SELECT t AS t, ((c * 8) + e) AS r, x AS x FROM t4),
  t2 AS (SELECT t AS t, (r / 2) AS c, (r % 2) AS e, x AS x FROM t3)
SELECT t, c, collect_as_array(LIST(e), LIST(x)) AS v FROM t2 GROUP BY t, c;"""


class TestChunkSQLSnapshots:
    """Pinned snapshots of chunk-size-annotated DDL, conversion SQL and the
    re-chunk-tail view for a chunk-planned pipeline, both dialects."""

    def _sql(self, dialect):
        pipe = _linear_pipe(cs=2)
        plan_layouts(pipe, mode="col", chunk_mode="auto",
                     chunk_candidates=(2, 4, 8))
        assert pipe.table_chunks == {"W__col": 8}
        return generate_sql(pipe, dialect=dialect, include_conversion=True)

    def test_duckdb_chunk_annotated_script(self):
        sql = self._sql("duckdb")
        assert GOLDEN_CHUNK_DDL_DUCKDB in sql
        assert GOLDEN_CHUNK_CONVERSION_DUCKDB in sql
        assert GOLDEN_CHUNK_VIEW_DUCKDB in sql
        # the ROW2COL source keeps the pipeline chunking
        assert "CREATE TABLE W (j INT32, c INT32, chunk FLOAT[2]);" in sql

    def test_ansi_chunk_annotated_script(self):
        sql = self._sql("ansi")
        assert GOLDEN_CHUNK_DDL_DUCKDB in sql  # DDL is dialect-invariant
        assert GOLDEN_CHUNK_CONVERSION_ANSI in sql
        assert GOLDEN_CHUNK_VIEW_ANSI in sql

    def test_rechunked_row_table_ddl_annotated(self):
        """A ROW_CHUNK table the planner re-chunked carries the chunk
        annotation and the new FLOAT width in its DDL."""
        g = build_prefill_graph(SPEC, 4)
        infer_shapes(g)
        pipe = op_map(g, chunk_size=8)
        plan_layouts(pipe, mode="auto", chunk_mode="auto",
                     chunk_candidates=(4, 8, 16, 32), budget_bytes=0)
        sql = generate_sql(pipe, dialect="duckdb")
        rechunked = [t for t, cs in pipe.table_chunks.items() if cs != 8]
        assert rechunked
        name = rechunked[0]
        cs = pipe.table_chunks[name]
        assert (f"-- layout: row_chunk; chunk_size: {cs} (planner)\n"
                f"CREATE TABLE {name} (") in sql
        assert f"chunk FLOAT[{cs}]);" in sql
