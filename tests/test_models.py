"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU asserting shapes and no NaNs; decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.registry import ASSIGNED
from repro.models import transformer as tf
from repro.training.optimizer import AdamW
from repro.training.train_loop import make_train_step

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, T=16):
    toks = jax.random.randint(RNG, (B, T), 0, cfg.vocab)
    batch = {"tokens": toks,
             "labels": jax.random.randint(RNG, (B, T), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(RNG, (B, cfg.n_frames,
                                                  cfg.d_model))
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(RNG, (B, cfg.n_image_tokens,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch):
    cfg = get_config(arch, tiny=True)
    params = tf.init_params(cfg, RNG)
    batch = _batch(cfg)
    logits = tf.forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_config(arch, tiny=True)
    params = tf.init_params(cfg, RNG)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt))
    state = opt.init(params)
    batch = _batch(cfg)
    new_params, new_state, metrics = step(params, state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state.step) == 1
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, tiny=True)
    params = tf.init_params(cfg, RNG)
    B, T, extra = 2, 12, 3
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, T + extra), 0,
                              cfg.vocab)
    batch = {"tokens": toks}
    aux_in = None
    if cfg.family == "encdec":
        aux_in = jax.random.normal(RNG, (B, cfg.n_frames, cfg.d_model))
        batch["frames"] = aux_in
    if cfg.family == "vlm":
        aux_in = jax.random.normal(RNG, (B, cfg.n_image_tokens, cfg.d_model))
        batch["images"] = aux_in
    full = tf.forward(params, batch, cfg)
    caches = tf.init_caches(cfg, B, T + extra, dtype=jnp.float32)
    lg, caches, aux_c = tf.prefill(params, toks[:, :T], cfg, caches,
                                   aux_input=aux_in)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, T - 1]),
                               rtol=1e-4, atol=1e-4)
    for i in range(extra):
        ld, caches = tf.decode_step(params, toks[:, T + i: T + i + 1], caches,
                                    jnp.asarray(T + i), cfg,
                                    aux_caches=aux_c)
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, T + i]),
                                   rtol=1e-4, atol=2e-4)


def test_scan_unroll_equivalence():
    """Dry-run unrolling must not change semantics."""
    import dataclasses
    cfg = get_config("qwen3-14b", tiny=True)
    params = tf.init_params(cfg, RNG)
    batch = _batch(cfg)
    a = tf.forward(params, batch, cfg)
    b = tf.forward(params, batch, dataclasses.replace(cfg, scan_unroll=10))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_moe_load_balance_loss_finite():
    from repro.models.moe import aux_load_balance_loss, moe_init
    cfg = get_config("olmoe-1b-7b", tiny=True)
    p = moe_init(RNG, cfg)
    x = jax.random.normal(RNG, (2, 8, cfg.d_model))
    lb = aux_load_balance_loss(p, x, cfg)
    assert np.isfinite(float(lb)) and float(lb) > 0


def test_param_count_sanity():
    """Analytic param counts track actual init sizes within 2%."""
    for arch in ("qwen3-14b", "olmoe-1b-7b", "mamba2-2.7b"):
        cfg = get_config(arch, tiny=True)
        params = tf.init_params(cfg, RNG)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)
