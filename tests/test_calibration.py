"""Calibration regression: fitting ``CostParams`` from the checked-in
benchmark JSONs must reproduce the measured layout preferences, and a
synthetic-timings fixture must round-trip known parameters exactly."""

import json
import pathlib

import numpy as np
import pytest

from repro.core.llama_graph import LlamaSpec
from repro.planner import CostParams
from repro.planner.calibrate import (CalibrationFit, cache_features,
                                     cache_points_from_payload,
                                     choose_base_chunk_size,
                                     fit_cache_weights, fit_cost_params,
                                     fit_matmul_weights,
                                     matmul_points_from_payload,
                                     pipeline_features)

ROOT = pathlib.Path(__file__).resolve().parents[1]
ROW2COL_JSON = ROOT / "BENCH_row2col.json"
ATTN_JSON = ROOT / "BENCH_attn_layout.json"

SPEC = LlamaSpec(vocab=64, d_model=32, n_layers=1, n_heads=4, n_kv=2,
                 d_ff=64, rope_theta=10000.0)


class TestSyntheticRoundTrip:
    """Timings generated *from* the cost model must fit back to the exact
    generating parameters (the fit is well-posed, not just plausible)."""

    def _synthetic_matmul_points(self, group_weight, scale, intercept):
        points = []
        for T in (4, 8):
            for cs in (4, 8):
                for kind, Teff in (("prefill", T), ("decode", 1)):
                    for mode in ("off", "col"):
                        rows, groups = pipeline_features(
                            SPEC, kind, Teff, cs, mode, cache_len=T + 4)
                        t = scale * (rows + group_weight * groups) + intercept
                        points.append((rows, groups, t))
        return points

    def test_matmul_weights_roundtrip(self):
        gw_true, scale_true, c0_true = 3.5, 0.02, 1500.0
        points = self._synthetic_matmul_points(gw_true, scale_true, c0_true)
        gw, scale, c0, resid = fit_matmul_weights(points)
        assert gw == pytest.approx(gw_true, rel=1e-6)
        assert scale == pytest.approx(scale_true, rel=1e-6)
        assert c0 == pytest.approx(c0_true, rel=1e-4)
        assert resid < 1e-6 * max(t for *_, t in points)

    def test_cache_weights_roundtrip(self):
        sw_true, scale_true, c0_true = 6.0, 0.05, 900.0
        points = []
        for cache_len in (16, 64, 128):
            for layout in ("row_chunk", "head_major", "pos_major"):
                scan, seeks = cache_features(SPEC, 8, cache_len, layout)
                points.append((scan, seeks,
                               scale_true * (scan + sw_true * seeks)
                               + c0_true))
        sw, scale, c0, resid = fit_cache_weights(points)
        assert sw == pytest.approx(sw_true, rel=1e-6)
        assert scale == pytest.approx(scale_true, rel=1e-6)
        assert resid < 1.0

    def test_fit_cost_params_roundtrip_via_files(self, tmp_path):
        """End-to-end: synthetic BENCH-format files → fitted CostParams."""
        gw_true, sw_true, scale = 2.25, 0.5, 0.01
        results = []
        for T in (4, 8):
            for cs in (4, 8):
                rec = {"seq_len": T, "chunk_size": cs}
                for kind, Teff in (("prefill", T), ("decode", 1)):
                    for mode in ("off", "col"):
                        rows, groups = pipeline_features(
                            SPEC, kind, Teff, cs, mode, cache_len=T + 8)
                        rec[f"{kind}_{mode}_us"] = scale * (
                            rows + gw_true * groups) + 100.0
                results.append(rec)
        row2col = {"spec": {"vocab": SPEC.vocab, "d_model": SPEC.d_model,
                            "n_layers": SPEC.n_layers, "d_ff": SPEC.d_ff,
                            "n_heads": SPEC.n_heads, "n_kv": SPEC.n_kv},
                   "results": results}
        arecs = []
        for cache_len in (16, 64):
            rec = {"cache_len": cache_len, "chunk_size": 8}
            for layout in ("row_chunk", "head_major", "pos_major"):
                scan, seeks = cache_features(SPEC, 8, cache_len, layout)
                rec[f"decode_{layout}_us"] = scale * (
                    scan + sw_true * seeks) + 100.0
            arecs.append(rec)
        attn = {"spec": row2col["spec"],
                "layouts": ["row_chunk", "head_major", "pos_major"],
                "results": arecs}
        p1, p2 = tmp_path / "r.json", tmp_path / "a.json"
        p1.write_text(json.dumps(row2col))
        p2.write_text(json.dumps(attn))
        fit = fit_cost_params(str(p1), str(p2))
        assert isinstance(fit, CalibrationFit)
        assert fit.params.group_weight == pytest.approx(gw_true, rel=1e-5)
        assert fit.params.seek_weight == pytest.approx(sw_true, rel=1e-5)
        assert fit.params.row_weight == 1.0

    def test_quant_fit_roundtrip_and_safe_fallbacks(self):
        """fit_quant_weights recovers generating weights exactly, and a
        *negative* fitted dequant slope (noise measuring quantised decode
        faster than f32) keeps the analytic default — zeroing it would
        make dequantisation free and flip precision='auto' into
        quantising everything with no memory pressure."""
        from repro.planner.calibrate import fit_quant_weights
        grid = [(24664.0, 0.0, 1_444_352), (24664.0, 360_448.0, 408_064),
                (24664.0, 720_896.0, 227_840), (125632.0, 0.0, 1_444_352),
                (125632.0, 360_448.0, 408_064),
                (125632.0, 720_896.0, 227_840)]
        dq_true, bw_true, s_true, c_true = 0.4, 0.03, 0.5, 40_000.0
        pts = [(f, d, b,
                c_true + s_true * (f + dq_true * d + bw_true * b))
               for f, d, b in grid]
        dq, bw, s, c0, resid = fit_quant_weights(pts)
        assert dq == pytest.approx(dq_true, rel=1e-5)
        assert bw == pytest.approx(bw_true, rel=1e-5)
        assert s == pytest.approx(s_true, rel=1e-5)
        neg = [(f, d, b, c_true + s_true * (f + 0.02 * b - 0.03 * d))
               for f, d, b in grid]
        dq2, bw2, *_ = fit_quant_weights(neg)
        assert dq2 == CostParams().dequant_weight
        assert bw2 >= 0

    def test_traced_dequant_times_fix_dispatch_dominated_fit(self):
        """ROADMAP carried item: a dispatch-dominated measurement set —
        totals that move *against* the dequant direction — degenerates
        the joint fit to the analytic default, but the same points plus
        traced ``dequant_project`` operator-class times recover the true
        weight (the profiler measures the direction the totals cannot
        resolve)."""
        from repro.planner.calibrate import fit_quant_weights
        grid = [(24664.0, 0.0, 1_444_352), (24664.0, 360_448.0, 408_064),
                (24664.0, 720_896.0, 227_840), (125632.0, 0.0, 1_444_352),
                (125632.0, 360_448.0, 408_064),
                (125632.0, 720_896.0, 227_840)]
        dq_true, bw_true, s_true, c_true = 0.4, 0.02, 0.5, 40_000.0
        pts = [(f, d, b, c_true + s_true * (f + bw_true * b - 0.03 * d))
               for f, d, b in grid]
        dq0, *_ = fit_quant_weights(pts)
        assert dq0 == CostParams().dequant_weight  # joint fit degenerates
        traced = [s_true * dq_true * d for _, d, _, _ in pts]
        dq, bw, s, c0, _ = fit_quant_weights(pts, traced)
        assert dq == pytest.approx(dq_true, rel=1e-5)
        assert s == pytest.approx(s_true, rel=1e-5)
        assert bw >= 0

    def test_traced_fit_ignores_untraced_gaps(self):
        """None entries (records without a profiled tick) drop out of the
        through-origin slope; the f32 record's traced zero at zero
        dequant elements contributes nothing."""
        from repro.planner.calibrate import fit_quant_weights
        grid = [(24664.0, 0.0, 1_444_352), (24664.0, 360_448.0, 408_064),
                (24664.0, 720_896.0, 227_840), (125632.0, 0.0, 1_444_352),
                (125632.0, 360_448.0, 408_064),
                (125632.0, 720_896.0, 227_840)]
        dq_true, s_true, c_true = 0.25, 0.5, 40_000.0
        pts = [(f, d, b, c_true + s_true * (f + 0.02 * b)) for f, d, b
               in grid]
        traced = [0.0 if d == 0 else
                  (None if f > 100_000 else s_true * dq_true * d)
                  for f, d, _, _ in pts]
        dq, _, s, _, _ = fit_quant_weights(pts, traced)
        assert dq == pytest.approx(dq_true, rel=1e-5)
        # an all-None (or all-zero-elements) trace falls back to the
        # joint fit — here degenerate, so the analytic default survives
        dq2, *_ = fit_quant_weights(pts, [None] * len(pts))
        assert dq2 == CostParams().dequant_weight

    def test_dequant_times_from_payload_alignment(self):
        """Extraction aligns 1:1 with quant_points_from_payload's point
        order (rec-major, prefill before decode) and distinguishes a
        traced zero from a missing trace."""
        from repro.planner.calibrate import dequant_times_from_payload
        payload = {"results": [
            {"precision": "f32", "prefill_us": 1.0, "decode_us": 2.0,
             "class_times_us": {"decode": {"scan": 5.0}}},
            {"precision": "int8", "prefill_us": 3.0, "decode_us": 4.0,
             "class_times_us": {"decode": {"dequant_project": 7.5}}},
        ]}
        times = dequant_times_from_payload(payload)
        # rec0: untraced prefill, traced decode with no dequant ops (0.0);
        # rec1: untraced prefill, traced decode with dequant time
        assert times == [None, 0.0, None, 7.5]
        assert dequant_times_from_payload(
            {"results": [{"precision": "f32", "decode_us": 2.0}]}) is None

    def test_fit_cost_params_uses_traced_dequant(self, tmp_path):
        """End-to-end through the payload file: a quant payload whose
        totals carry no dequant signal at all still calibrates
        ``dequant_weight`` when its records carry traced
        ``dequant_project`` class times."""
        from repro.planner.calibrate import fit_cost_params
        cs = 8
        p = CostParams()
        feats = {}
        for kind, Teff in (("prefill", 4), ("decode", 1)):
            rows, groups = pipeline_features(SPEC, kind, Teff, cs, "auto",
                                             cache_len=12, params=p)
            feats[kind] = rows + p.group_weight * groups
        dq_true, bw_true, s, c0 = 0.7, 0.01, 0.4, 25_000.0
        results = []
        for prec, d, b in (("f32", 0.0, 600_000), ("int8", 150_000.0,
                                                   180_000),
                           ("nf4", 300_000.0, 110_000)):
            rec = {"precision": prec, "resident_weight_bytes": b,
                   "dequant_cost_elements": d, "class_times_us": {}}
            for kind in ("prefill", "decode"):
                rec[f"{kind}_us"] = c0 + s * (feats[kind] + bw_true * b)
                rec["class_times_us"][kind] = {
                    "dequant_project": s * dq_true * d}
            results.append(rec)
        payload = {"spec": {"vocab": SPEC.vocab, "d_model": SPEC.d_model,
                            "n_layers": SPEC.n_layers,
                            "n_heads": SPEC.n_heads, "n_kv": SPEC.n_kv,
                            "d_ff": SPEC.d_ff},
                   "chunk_size": cs, "prompt_tokens": 4, "cache_len": 12,
                   "results": results}
        qp = tmp_path / "q.json"
        qp.write_text(json.dumps(payload))
        fit = fit_cost_params(None, None, quant_path=str(qp))
        assert fit.params.dequant_weight == pytest.approx(dq_true,
                                                          rel=1e-4)
        assert fit.params.dequant_weight != CostParams().dequant_weight

    def test_cold_points_recover_byte_weight(self):
        """Warm totals carry almost no byte signal (the resident working
        set never leaves RAM), so the joint fit's byte slope is noise;
        disk-backed cold-cache points — one shared byte slope with a
        per-kind intercept — recover the true ``byte_weight``."""
        from repro.planner.calibrate import fit_quant_weights
        grid = [(24664.0, 0.0, 1_444_352), (24664.0, 360_448.0, 408_064),
                (24664.0, 720_896.0, 227_840), (125632.0, 0.0, 1_444_352),
                (125632.0, 360_448.0, 408_064),
                (125632.0, 720_896.0, 227_840)]
        dq_true, bw_true, s_true, c_true = 0.4, 0.08, 0.5, 40_000.0
        # totals: zero byte direction — cold points must supply it.  The
        # cold runs also re-dequantise what they re-stream (a dequant
        # term anti-correlated with bytes — quantised tables are small
        # but dequant-heavy); the nuisance column keeps it from
        # confounding the byte slope.
        pts = [(f, d, b, c_true + s_true * (f + dq_true * d))
               for f, d, b in grid]
        cold = [(kind, b, d, c_kind + s_true * (bw_true * b + 0.9 * d))
                for kind, c_kind in (("prefill", 90_000.0),
                                     ("decode", 55_000.0))
                for _, d, b in grid[:3]]
        dq, bw, s, _, _ = fit_quant_weights(pts, cold_points=cold)
        assert dq == pytest.approx(dq_true, rel=1e-5)
        assert bw == pytest.approx(bw_true, rel=1e-5)
        assert s == pytest.approx(s_true, rel=1e-5)

    def test_negative_cold_slope_keeps_joint_fit(self):
        """A negative cold byte slope (noise: bigger tables timed faster)
        must not poison the fit — the joint fit's byte weight survives."""
        from repro.planner.calibrate import fit_quant_weights
        grid = [(24664.0, 0.0, 1_444_352), (24664.0, 360_448.0, 408_064),
                (24664.0, 720_896.0, 227_840), (125632.0, 0.0, 1_444_352),
                (125632.0, 360_448.0, 408_064),
                (125632.0, 720_896.0, 227_840)]
        dq_true, bw_true, s_true, c_true = 0.4, 0.03, 0.5, 40_000.0
        pts = [(f, d, b,
                c_true + s_true * (f + dq_true * d + bw_true * b))
               for f, d, b in grid]
        bad = [("decode", b, d, 90_000.0 - 0.01 * b)
               for _, d, b in grid[:3]]
        _, bw, *_ = fit_quant_weights(pts, cold_points=bad)
        assert bw == pytest.approx(bw_true, rel=1e-5)
        # too few cold points for a determined fit: same survival
        _, bw2, *_ = fit_quant_weights(
            pts, cold_points=[("decode", 1_444_352.0, 0.0, 99_000.0),
                              ("decode", 408_064.0, 360_448.0, 95_000.0)])
        assert bw2 == pytest.approx(bw_true, rel=1e-5)

    def test_cold_points_from_payload(self):
        """Extraction yields (kind, bytes, dequant_elems, time_us) quads
        rec-major, prefill before decode, and is empty for pre-cold-mode
        payloads."""
        from repro.planner.calibrate import cold_points_from_payload
        payload = {"results": [
            {"precision": "f32", "resident_weight_bytes": 600_000,
             "prefill_cold_us": 11.0, "decode_cold_us": 7.0},
            {"precision": "int8", "resident_weight_bytes": 180_000,
             "dequant_cost_elements": 150_000.0, "decode_cold_us": 5.0},
        ]}
        assert cold_points_from_payload(payload) == [
            ("prefill", 600_000.0, 0.0, 11.0),
            ("decode", 600_000.0, 0.0, 7.0),
            ("decode", 180_000.0, 150_000.0, 5.0)]
        assert cold_points_from_payload(
            {"results": [{"precision": "f32", "decode_us": 2.0}]}) == []

    def test_fit_cost_params_uses_cold_points(self, tmp_path):
        """End-to-end through the payload file: warm totals with no byte
        signal still calibrate ``byte_weight`` when the records carry
        disk-backed ``{prefill,decode}_cold_us`` timings."""
        from repro.planner.calibrate import fit_cost_params
        cs = 8
        p = CostParams()
        feats = {}
        for kind, Teff in (("prefill", 4), ("decode", 1)):
            rows, groups = pipeline_features(SPEC, kind, Teff, cs, "auto",
                                             cache_len=12, params=p)
            feats[kind] = rows + p.group_weight * groups
        dq_true, bw_true, s, c0 = 0.7, 0.05, 0.4, 25_000.0
        cold_c = {"prefill": 70_000.0, "decode": 45_000.0}
        results = []
        for prec, d, b in (("f32", 0.0, 600_000), ("int8", 150_000.0,
                                                   180_000),
                           ("nf4", 300_000.0, 110_000)):
            rec = {"precision": prec, "resident_weight_bytes": b,
                   "dequant_cost_elements": d}
            for kind in ("prefill", "decode"):
                rec[f"{kind}_us"] = c0 + s * (feats[kind] + dq_true * d)
                rec[f"{kind}_cold_us"] = cold_c[kind] + s * (
                    bw_true * b + 0.9 * d)  # reload re-dequantises too
            results.append(rec)
        payload = {"spec": {"vocab": SPEC.vocab, "d_model": SPEC.d_model,
                            "n_layers": SPEC.n_layers,
                            "n_heads": SPEC.n_heads, "n_kv": SPEC.n_kv,
                            "d_ff": SPEC.d_ff},
                   "chunk_size": cs, "prompt_tokens": 4, "cache_len": 12,
                   "results": results}
        qp = tmp_path / "q.json"
        qp.write_text(json.dumps(payload))
        fit = fit_cost_params(None, None, quant_path=str(qp))
        assert fit.params.byte_weight == pytest.approx(bw_true, rel=1e-4)
        assert fit.params.dequant_weight == pytest.approx(dq_true,
                                                          rel=1e-4)

    def test_missing_files_keep_defaults(self, tmp_path):
        base = CostParams()
        fit = fit_cost_params(str(tmp_path / "nope.json"),
                              str(tmp_path / "also_nope.json"), base=base,
                              quant_path=str(tmp_path / "no_quant.json"))
        assert fit.params.group_weight == base.group_weight
        assert fit.params.seek_weight == base.seek_weight
        assert fit.params.dequant_weight == base.dequant_weight
        assert fit.params.byte_weight == base.byte_weight
        assert fit.n_points == 0


@pytest.fixture(scope="module")
def checked_in_fit():
    return fit_cost_params(str(ROW2COL_JSON), str(ATTN_JSON))


class TestCheckedInBenches:
    """Regression against the committed measurement files: the calibrated
    weights must stay finite and keep reproducing the measured rankings."""

    def test_fit_is_finite_and_bounded(self, checked_in_fit):
        p = checked_in_fit.params
        assert np.isfinite(p.group_weight) and p.group_weight >= 0
        assert np.isfinite(p.seek_weight) and 0 <= p.seek_weight
        # the dense JAX executor shows no *stronger* seek sensitivity than
        # the analytic default assumed: a resolved fit comes out smaller,
        # and a dispatch-dominated measurement set (per-step time flat in
        # scan rows) degenerates to exactly the analytic default by design
        # — either way the calibrated weight must not exceed it
        assert p.seek_weight <= CostParams().seek_weight
        assert checked_in_fit.scale_us > 0
        assert checked_in_fit.n_points > 0

    def test_decode_layout_ranking_reproduced(self, checked_in_fit):
        """Wherever the measured decode row-vs-col gap is decisive (>5%),
        the calibrated model must prefer the measured-faster layout."""
        payload = json.loads(ROW2COL_JSON.read_text())
        from repro.planner.calibrate import _spec_from_payload
        spec = _spec_from_payload(payload["spec"])
        p = checked_in_fit.params
        checked = 0
        for rec in payload["results"]:
            T, cs = rec["seq_len"], rec["chunk_size"]
            off, col = rec["decode_off_us"], rec["decode_col_us"]
            if abs(off / col - 1) <= 0.05:
                continue  # measured tie: either choice is fine
            ro, go = pipeline_features(spec, "decode", 1, cs, "off",
                                       cache_len=T + 8)
            rc, gc = pipeline_features(spec, "decode", 1, cs, "col",
                                       cache_len=T + 8)
            model_prefers_col = (rc + p.group_weight * gc) < (
                ro + p.group_weight * go)
            assert model_prefers_col == (col < off), (T, cs)
            checked += 1
        assert checked >= 3  # the committed file has decisive configs

    def test_cache_layout_ranking_reproduced(self, checked_in_fit):
        """The calibrated locality model must (a) keep the decisive
        measured ordering head_major < row_chunk at the largest cache
        length and (b) choose a layout whose measured time is within 2%
        of the measured optimum there."""
        payload = json.loads(ATTN_JSON.read_text())
        from repro.planner.calibrate import _spec_from_payload
        spec = _spec_from_payload(payload["spec"])
        p = checked_in_fit.params
        rec = max(payload["results"], key=lambda r: r["cache_len"])
        pred, meas = {}, {}
        for layout in payload["layouts"]:
            scan, seeks = cache_features(spec, rec["chunk_size"],
                                         rec["cache_len"], layout)
            pred[layout] = scan + p.seek_weight * seeks
            meas[layout] = rec[f"decode_{layout}_us"]
        assert pred["head_major"] < pred["row_chunk"]
        assert meas["head_major"] < meas["row_chunk"]
        top = min(pred, key=pred.get)
        assert meas[top] <= 1.02 * min(meas.values())

    def test_calibrated_chunk_choice_is_admissible(self, checked_in_fit):
        spec = LlamaSpec(vocab=256, d_model=128, n_layers=2, n_heads=4,
                         n_kv=2, d_ff=256, rope_theta=10000.0)
        pick = choose_base_chunk_size(spec, cache_len=48, prefill_tokens=16,
                                      candidates=(8, 16, 32),
                                      params=checked_in_fit.params)
        assert pick in (8, 16, 32)
        # deterministic
        again = choose_base_chunk_size(spec, cache_len=48,
                                       prefill_tokens=16,
                                       candidates=(8, 16, 32),
                                       params=checked_in_fit.params)
        assert pick == again

    def test_no_admissible_candidate_raises(self):
        with pytest.raises(ValueError):
            choose_base_chunk_size(SPEC, candidates=(7,))


class TestPointExtraction:
    def test_matmul_points_cover_all_measurements(self):
        payload = json.loads(ROW2COL_JSON.read_text())
        points = matmul_points_from_payload(payload)
        # prefill/decode × off/col per record
        assert len(points) == 4 * len(payload["results"])
        assert all(r > 0 and g > 0 and t > 0 for r, g, t in points)

    def test_cache_points_cover_all_measurements(self):
        payload = json.loads(ATTN_JSON.read_text())
        points = cache_points_from_payload(payload)
        assert len(points) == len(payload["layouts"]) * len(
            payload["results"])
        assert all(s > 0 and t > 0 for s, _, t in points)
