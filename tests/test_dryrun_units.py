"""Dry-run machinery units: HLO collective-byte parsing, roofline math,
cell skip policy, input specs — no device mesh required."""

import jax
import numpy as np
import pytest

from repro.analysis.roofline import (ICI_BW, PEAK_FLOPS, HBM_BW, Roofline,
                                     collective_bytes, model_flops_for)
from repro.configs import get_config
from repro.launch.specs import SHAPES, batch_specs, cell_supported

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ar = bf16[1024,512]{1,0} all-reduce(bf16[1024,512]{1,0} %p0), replica_groups={}
  %ag = f32[256,128]{1,0} all-gather(f32[64,128]{1,0} %x), dimensions={0}
  %rs = f32[16,128]{1,0} reduce-scatter(f32[64,128]{1,0} %y), dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(bf16[32,32]{1,0} %z), source_target_pairs={{0,1}}
  %dot = f32[64,64]{1,0} dot(f32[64,32]{1,0} %a, f32[32,64]{1,0} %b)
}
"""


class TestCollectiveParser:
    def test_counts_each_kind(self):
        out = collective_bytes(HLO_SAMPLE)
        assert out["all-reduce"] == 1024 * 512 * 2
        assert out["all-gather"] == 64 * 128 * 4
        assert out["reduce-scatter"] == 64 * 128 * 4
        assert out["collective-permute"] == 32 * 32 * 2
        assert out["total"] == sum(
            out[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute",
                             "collective-broadcast"))

    def test_ignores_non_collectives(self):
        out = collective_bytes("%d = f32[8,8] dot(f32[8,8] %a, f32[8,8] %b)")
        assert out["total"] == 0

    def test_real_compiled_module(self):
        """Parse the HLO of an actually-compiled psum."""
        import jax.numpy as jnp
        fn = jax.jit(lambda x: x @ x.T)
        txt = fn.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()\
            .as_text()
        out = collective_bytes(txt)
        assert out["total"] == 0  # single device: no collectives


class TestRooflineMath:
    def _rl(self, flops, bytes_, coll):
        return Roofline(arch="a", shape="s", mesh="m", flops_per_dev=flops,
                        bytes_per_dev=bytes_, coll_bytes_per_dev=coll,
                        coll_breakdown={}, model_flops=flops / 2)

    def test_terms(self):
        rl = self._rl(PEAK_FLOPS, HBM_BW, ICI_BW)
        assert rl.t_compute == pytest.approx(1.0)
        assert rl.t_memory == pytest.approx(1.0)
        assert rl.t_collective == pytest.approx(1.0)

    def test_bottleneck_selection(self):
        rl = self._rl(PEAK_FLOPS, 10 * HBM_BW, ICI_BW)
        assert rl.bottleneck == "memory"
        assert rl.bound_time == pytest.approx(10.0)
        assert rl.roofline_fraction == pytest.approx(0.1)

    def test_useful_ratio(self):
        rl = self._rl(2e12, 1, 1)
        assert rl.useful_flops_ratio == pytest.approx(0.5)

    def test_model_flops_train_vs_decode(self):
        cfg = get_config("qwen3-14b")
        tr = model_flops_for(cfg, SHAPES["train_4k"], 256, "train")
        de = model_flops_for(cfg, SHAPES["decode_32k"], 256, "decode")
        # train: 6·N·(4096·256) / chips;  decode: 2·N·128 / chips
        assert tr / de == pytest.approx(3 * 4096 * 256 / 128, rel=1e-6)


class TestCellPolicy:
    def test_long500k_skips_full_attention(self):
        ok, why = cell_supported(get_config("qwen3-14b"), "long_500k")
        assert not ok and "full-attention" in why

    @pytest.mark.parametrize("arch", ["mamba2-2.7b", "hymba-1.5b"])
    def test_long500k_runs_subquadratic(self, arch):
        ok, _ = cell_supported(get_config(arch), "long_500k")
        assert ok

    def test_all_40_cells_accounted(self):
        """10 archs × 4 shapes: every cell either supported or documented."""
        from repro.configs.registry import ASSIGNED
        total = supported = skipped = 0
        for arch in ASSIGNED:
            for shape in SHAPES:
                total += 1
                ok, why = cell_supported(get_config(arch), shape)
                supported += ok
                skipped += (not ok) and bool(why)
        assert total == 40
        assert supported + skipped == 40
        assert skipped == 8  # long_500k × 8 full-attention archs

    def test_batch_specs_stub_frontends(self):
        wh = batch_specs(get_config("whisper-small"), 4096, 256)
        assert wh["frames"].shape == (256, 1500, 768)
        vl = batch_specs(get_config("llama-3.2-vision-90b"), 4096, 256)
        assert vl["images"].shape == (256, 6404, 8192)
