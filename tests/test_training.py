"""Training substrate: optimizer, loss descent, checkpoint/restore,
fault-tolerant restart drivers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tf
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import FaultConfig, run_with_recovery
from repro.training.optimizer import AdamW, clip_by_global_norm, global_norm
from repro.training.train_loop import make_train_step


class TestOptimizer:
    def test_adamw_converges_on_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=0,
                    total_steps=200, min_lr_ratio=1.0)
        params = {"w": jnp.asarray([[3.0, -2.0]])}
        state = opt.init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_bf16_state_halves_memory(self):
        params = {"w": jnp.zeros((128, 128), jnp.float32)}
        s32 = AdamW(state_dtype="float32").init(params)
        s16 = AdamW(state_dtype="bfloat16").init(params)
        assert s16.m["w"].dtype == jnp.bfloat16
        assert s16.m["w"].nbytes * 2 == s32.m["w"].nbytes

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


class TestTrainLoop:
    def _setup(self, grad_accum=1):
        cfg = get_config("llama3-8b", tiny=True)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=3e-3, warmup_steps=2, total_steps=60)
        step = jax.jit(make_train_step(cfg, opt, grad_accum=grad_accum))
        return cfg, params, opt, step

    def test_loss_decreases(self):
        cfg, params, opt, step = self._setup()
        state = opt.init(params)
        data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8)
        losses = []
        for i in range(30):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, state, m = step(params, state, b)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3

    def test_grad_accum_equivalence(self):
        """k microbatches of size b == one batch of size k·b (same grads)."""
        cfg, params, opt, _ = self._setup()
        step1 = make_train_step(cfg, opt, grad_accum=1)
        step4 = make_train_step(cfg, opt, grad_accum=4)
        state = opt.init(params)
        data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=8)
        b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        p1, _, m1 = step1(params, state, b)
        p4, _, m4 = step4(params, state, b)
        d = jax.tree_util.tree_map(
            lambda a, c: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - c.astype(jnp.float32)))),
            p1, p4)
        # reduction-order noise between the two accumulation schedules is
        # amplified by Adam's per-parameter normalisation (near-zero grads
        # flip sign, moving the update by up to ±lr); in default f32 the
        # observed worst case on CPU is ~6e-4, so the tolerance is
        # per-dtype rather than the old flaky flat 5e-4
        tol = 5e-4 if jax.config.jax_enable_x64 else 2e-3
        assert max(jax.tree_util.tree_leaves(d)) < tol
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3


class TestCheckpoint:
    def test_save_restore_exact(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        ckpt.save(str(tmp_path), 7, tree, extra={"data_cursor": 7})
        abstract = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
        got, manifest = ckpt.restore(str(tmp_path), None, abstract)
        assert manifest["step"] == 7
        assert manifest["extra"]["data_cursor"] == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32),
                                                       np.asarray(b, np.float32)),
            tree, got)

    def test_atomic_rename_no_tmp_left(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"x": jnp.zeros(3)})
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))

    def test_garbage_collect_keeps_latest(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            ckpt.save(str(tmp_path), s, {"x": jnp.zeros(2)})
        ckpt.garbage_collect(str(tmp_path), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 5
        assert len(os.listdir(tmp_path)) == 2

    def test_async_checkpointer(self, tmp_path):
        acp = ckpt.AsyncCheckpointer(str(tmp_path))
        acp.save(3, {"x": jnp.full((8,), 3.0)})
        acp.wait()
        assert ckpt.latest_step(str(tmp_path)) == 3


class TestFaultTolerance:
    def _driver_parts(self, tmp_path):
        cfg = get_config("llama3-8b", tiny=True)
        opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=40)
        step = jax.jit(make_train_step(cfg, opt))
        data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=4)

        def init_state():
            p = tf.init_params(cfg, jax.random.PRNGKey(0))
            return p, opt.init(p)

        def batch_at(i):
            return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

        return step, init_state, batch_at

    def test_restart_reproduces_uninterrupted_run(self, tmp_path):
        step, init_state, batch_at = self._driver_parts(tmp_path)
        # uninterrupted reference
        ref = run_with_recovery(
            step, init_state, batch_at, total_steps=12,
            fault_cfg=FaultConfig(ckpt_dir=str(tmp_path / "ref"),
                                  ckpt_every=4))
        # crash at step 9 (after the step-8 checkpoint), then resume
        rec = run_with_recovery(
            step, init_state, batch_at, total_steps=12,
            fault_cfg=FaultConfig(ckpt_dir=str(tmp_path / "ft"),
                                  ckpt_every=4),
            fail_at={9: 0})
        assert rec.restarts == 1
        assert ref.steps_run == rec.steps_run == 12
        # bitwise-identical final loss: data cursor + params restored exactly
        assert rec.losses[-1] == pytest.approx(ref.losses[-1], abs=1e-6)

    def test_multiple_failures(self, tmp_path):
        step, init_state, batch_at = self._driver_parts(tmp_path)
        rec = run_with_recovery(
            step, init_state, batch_at, total_steps=10,
            fault_cfg=FaultConfig(ckpt_dir=str(tmp_path / "ft2"),
                                  ckpt_every=2, max_restarts=5),
            fail_at={3: 0, 7: 1})
        assert rec.restarts == 2
        assert rec.steps_run == 10
